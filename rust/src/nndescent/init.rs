//! Random K-NN graph initialization (paper §2): every node starts with
//! k neighbors sampled uniformly at random, real distances attached,
//! all flagged "new".

use crate::cachesim::trace::Tracer;
use crate::dataset::AlignedMatrix;
use crate::graph::KnnGraph;
use crate::util::counters::FlopCounter;
use crate::util::rng::Pcg64;

/// Fill `graph` with k uniformly sampled neighbors per node.
pub fn init_random<T: Tracer>(
    graph: &mut KnnGraph,
    data: &AlignedMatrix,
    rng: &mut Pcg64,
    counter: &mut FlopCounter,
    tracer: &mut T,
) {
    let n = graph.n();
    let k = graph.k().min(n - 1);
    let row_bytes = data.row_bytes() as u32;
    // resolve the dispatched pair kernel once for the n·k init scan
    let pair = crate::distance::dispatch::active().pair;
    let mut sample: Vec<u32> = Vec::with_capacity(k);
    for u in 0..n {
        // k distinct ids ≠ u by rejection (k ≪ n, expected O(k) draws;
        // falls back to dense reservoir sampling for tiny n where
        // rejection would thrash)
        sample.clear();
        if n <= 2 * k + 2 {
            rng.sample_indices(n - 1, k, &mut sample);
            for raw in sample.iter_mut() {
                if (*raw as usize) >= u {
                    *raw += 1;
                }
            }
        } else {
            while sample.len() < k {
                let v = rng.gen_index(n) as u32;
                if v as usize != u && !sample.contains(&v) {
                    sample.push(v);
                }
            }
        }
        tracer.read(data.base_addr() + u * data.row_bytes(), row_bytes);
        let a = data.row(u);
        for &v in sample.iter() {
            tracer.read(data.base_addr() + v as usize * data.row_bytes(), row_bytes);
            let d = pair(a, data.row(v as usize));
            counter.add_evals(1);
            graph.push(u, v, d, true);
        }
    }
}

/// Parallel init for the T>1 engine: each node draws its k random
/// neighbors from its **own** counter-based stream (keyed by node id,
/// never by worker), so the starting graph is a pure function of
/// `(seed, data)` — deterministic and thread-count invariant, exactly
/// like the engine's select/compute phases. It is a *different*,
/// equally-uniform random graph than the sequential stream walk
/// produces, which is fine: the T>1 engine's results already differ
/// from T=1's (same algorithm family, gated equal quality).
///
/// Workers buffer their ranges' edges; the driver replays them into the
/// graph in node order afterwards, so heap insertion order and eval
/// accounting (exactly `n·k` evaluations) match the sequential init
/// discipline.
pub fn init_random_parallel(
    graph: &mut KnnGraph,
    data: &AlignedMatrix,
    seed: u64,
    bounds: &[std::ops::Range<usize>],
    counter: &mut FlopCounter,
) {
    let n = graph.n();
    let k = graph.k().min(n - 1);
    let pair = crate::distance::dispatch::active().pair;
    let mut buffers: Vec<Vec<(u32, f32)>> =
        bounds.iter().map(|r| Vec::with_capacity(r.len() * k)).collect();
    std::thread::scope(|s| {
        for (range, buf) in bounds.iter().zip(buffers.iter_mut()) {
            let range = range.clone();
            s.spawn(move || {
                let mut sample: Vec<u32> = Vec::with_capacity(k);
                for u in range {
                    // one distinct stream per node: any worker owning u
                    // draws the identical sample
                    let mut rng = Pcg64::new_stream(seed ^ 0x1217_AB1E, u as u64);
                    sample.clear();
                    if n <= 2 * k + 2 {
                        rng.sample_indices(n - 1, k, &mut sample);
                        for raw in sample.iter_mut() {
                            if (*raw as usize) >= u {
                                *raw += 1;
                            }
                        }
                    } else {
                        while sample.len() < k {
                            let v = rng.gen_index(n) as u32;
                            if v as usize != u && !sample.contains(&v) {
                                sample.push(v);
                            }
                        }
                    }
                    let a = data.row(u);
                    for &v in sample.iter() {
                        buf.push((v, pair(a, data.row(v as usize))));
                    }
                }
            });
        }
    });
    for (range, buf) in bounds.iter().zip(buffers) {
        let mut edges = buf.into_iter();
        for u in range.clone() {
            for _ in 0..k {
                let (v, d) = edges.next().expect("exactly k edges buffered per node");
                counter.add_evals(1);
                graph.push(u, v, d, true);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cachesim::trace::NoTracer;
    use crate::dataset::synth::SynthGaussian;
    use crate::distance::sq_l2_unrolled;
    use crate::graph::heap::EMPTY_ID;

    fn setup(n: usize, k: usize, dim: usize) -> (KnnGraph, AlignedMatrix, FlopCounter) {
        let data = SynthGaussian::single(n, dim, 3).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut rng = Pcg64::new(7);
        let mut counter = FlopCounter::new(dim);
        init_random(&mut graph, &data, &mut rng, &mut counter, &mut NoTracer);
        (graph, data, counter)
    }

    #[test]
    fn fills_every_slot_with_distinct_neighbors() {
        let (graph, _, counter) = setup(100, 10, 8);
        for u in 0..100 {
            let ids = graph.ids(u);
            assert!(ids.iter().all(|&v| v != EMPTY_ID && v as usize != u));
            let mut s: Vec<u32> = ids.to_vec();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 10, "node {u} has duplicate neighbors");
        }
        assert_eq!(counter.dist_evals, 100 * 10);
        graph.validate().unwrap();
    }

    #[test]
    fn distances_are_correct() {
        let (graph, data, _) = setup(50, 5, 16);
        for u in 0..50 {
            for (&v, &d) in graph.ids(u).iter().zip(graph.dists(u)) {
                let expect = sq_l2_unrolled(data.row(u), data.row(v as usize));
                assert!((d - expect).abs() < 1e-5, "node {u} → {v}: {d} vs {expect}");
            }
        }
    }

    #[test]
    fn all_flags_start_new() {
        let (graph, _, _) = setup(30, 4, 8);
        for u in 0..30 {
            assert!(graph.flags(u).iter().all(|&f| f));
        }
    }

    fn parallel_setup(n: usize, k: usize, workers: usize) -> (KnnGraph, FlopCounter) {
        let data = SynthGaussian::single(n, 8, 3).generate();
        let mut graph = KnnGraph::new(n, k);
        let mut counter = FlopCounter::new(8);
        let bounds: Vec<std::ops::Range<usize>> =
            (0..workers).map(|w| w * n / workers..(w + 1) * n / workers).collect();
        init_random_parallel(&mut graph, &data, 42, &bounds, &mut counter);
        (graph, counter)
    }

    #[test]
    fn parallel_init_is_valid_and_fully_counted() {
        let (graph, counter) = parallel_setup(200, 8, 4);
        for u in 0..200 {
            let ids = graph.ids(u);
            assert!(ids.iter().all(|&v| v != EMPTY_ID && v as usize != u));
            let mut s: Vec<u32> = ids.to_vec();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 8, "node {u} has duplicate neighbors");
        }
        assert_eq!(counter.dist_evals, 200 * 8, "init accounts exactly n·k evals");
        graph.validate().unwrap();
    }

    #[test]
    fn parallel_init_is_worker_count_invariant() {
        // per-node streams: the partition into ranges must not matter
        let (base, _) = parallel_setup(300, 6, 1);
        for workers in [2usize, 3, 7] {
            let (other, _) = parallel_setup(300, 6, workers);
            for u in 0..300 {
                assert_eq!(base.sorted(u), other.sorted(u), "workers={workers} node {u}");
            }
        }
    }

    #[test]
    fn k_clamped_when_n_small() {
        let data = SynthGaussian::single(4, 8, 1).generate();
        let mut graph = KnnGraph::new(4, 6); // k > n-1
        let mut rng = Pcg64::new(1);
        let mut c = FlopCounter::new(8);
        init_random(&mut graph, &data, &mut rng, &mut c, &mut NoTracer);
        for u in 0..4 {
            let filled = graph.ids(u).iter().filter(|&&v| v != EMPTY_ID).count();
            assert_eq!(filled, 3, "only n-1 distinct neighbors exist");
        }
        graph.validate().unwrap();
    }
}
