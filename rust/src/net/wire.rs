//! `KNNQv1` — the length-prefixed binary wire protocol for network
//! serving, versioned and checksummed in the same style as the
//! `KNNIv1` index bundle (`search::bundle`): magic, version, flags,
//! FNV-1a CRC trailer, typed errors instead of panics.
//!
//! Every frame on the wire is:
//!
//! ```text
//! len      4 B   u32 LE — byte length of the payload that follows
//! payload:
//!   magic    4 B   "KNNQ"
//!   version  1 B   u8 (currently 3)
//!   kind     1 B   u8 (frame kind, see below)
//!   flags    2 B   u16 LE (must be 0 in v1)
//!   body     …     kind-specific, little-endian
//!   crc      8 B   u64 LE — FNV-1a over magic..body
//! ```
//!
//! Frame kinds:
//!
//! | kind | frame       | body |
//! |-----:|-------------|------|
//! | 1    | Ping        | `token u64` |
//! | 2    | Pong        | `token u64, n u64, dim u32, k u32` |
//! | 3    | Query       | `k u32, route_top_m u32 (0 = full fan-out), count u32, dim u32, deadline_us u64 (0 = none; v2+), count·dim × f32` |
//! | 4    | Results     | `count u32, k u32`, per query `cnt u32 + cnt × (id u32, dist f32)`, per query `requests u32, unique u32, coalesced u8` |
//! | 5    | Error       | `code u8, detail u32, msg_len u16, msg_len × utf-8` |
//! | 6    | Shutdown    | empty |
//! | 7    | Degraded    | `cause u8, missing u32, missing × u32 (shard ids), missing × u32 (replicas tried; v3+)`, then a Results body (v2+) |
//! | 8    | Health      | `token u64` (v2+) |
//! | 9    | HealthReply | `token u64, threads u32, respawns u64, panics u64, lost u64, misses u64, shards u32, shards × u8 (1 = alive)`, then `replicas u32, hedges u64, hedge_wins u64, failovers u64, rcount u32, rcount × u8 (1 = alive, shard-major)` (v3+) (v2+) |
//! | 10   | Insert      | `id u32, dim u32, dim × f32` (v2+) |
//! | 11   | Delete      | `id u32` (v2+) |
//! | 12   | Compact     | empty (v2+) |
//! | 13   | MutateOk    | `op u8, applied u8, generation u64, live u64` (v2+) |
//!
//! Version 2 added `deadline_us` to Query, the three fault-tolerance
//! kinds (7–9), and the storage-engine mutation kinds (10–13: see
//! [`crate::store`]). Version 3 extends Degraded with a per-missing-
//! shard replicas-tried count and HealthReply with the replication
//! snapshot (replica count, hedge/failover counters, per-replica
//! liveness). Version 1 and 2 frames still decode — a v1 Query has no
//! deadline field and comes back as `deadline_us == 0` ("no
//! deadline"); a v2 Degraded decodes with zeroed replicas-tried and a
//! v2 HealthReply as an unreplicated pool (`replicas == 1`, zero
//! hedge/failover counters, replica liveness mirroring the shard
//! liveness) — so legacy clients keep working unchanged. This build
//! always writes version 3.
//!
//! `f32` values cross the wire as their exact little-endian bit
//! patterns (`to_le_bytes`/`from_le_bytes`), so NaN payloads and
//! `-0.0` survive a round trip — the loopback bit-identity contract
//! rests on this.
//!
//! Decoding **never panics**: every read is bounds-checked and every
//! failure is a typed [`WireError`]. A [`WireError::Protocol`] whose
//! [`desync`](WireError::Protocol::desync) flag is false consumed
//! exactly `len` payload bytes, so the stream is still framed and the
//! connection can answer with an [`Frame::Error`] and keep serving;
//! `desync: true` means the length prefix itself was untrustworthy and
//! the connection must close.

use crate::api::{DegradeCause, Degradation, Neighbor, WindowInfo};
use crate::graph::io::Fnv;
use std::io::{Read, Write};

/// Magic bytes opening every `KNNQv1` payload.
pub const MAGIC: &[u8; 4] = b"KNNQ";
/// Protocol version this build writes.
pub const VERSION: u8 = 3;
/// Oldest version this build still decodes (v1: no query deadlines, no
/// degraded/health kinds; v2: no replication fields).
pub const LEGACY_VERSION: u8 = 1;
/// Smallest legal payload: magic + version + kind + flags + crc.
pub const MIN_PAYLOAD: usize = 16;
/// Default cap on the payload length prefix (16 MiB); anything larger
/// is rejected as [`ErrorCode::Oversized`] without being read.
pub const DEFAULT_MAX_FRAME: usize = 16 << 20;

/// `op` byte of a [`Frame::MutateOk`] answering an insert.
pub const MUTATE_OP_INSERT: u8 = 1;
/// `op` byte of a [`Frame::MutateOk`] answering a delete.
pub const MUTATE_OP_DELETE: u8 = 2;
/// `op` byte of a [`Frame::MutateOk`] answering a compaction.
pub const MUTATE_OP_COMPACT: u8 = 3;

/// Typed error codes carried by [`Frame::Error`] (and mirrored in
/// [`WireError::Protocol`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Structurally invalid frame: bad magic, bad CRC, nonzero flags,
    /// body/byte-count mismatch, trailing junk, unknown kind.
    Malformed = 1,
    /// The version byte is not one this server speaks (`detail` = the
    /// offered version).
    UnsupportedVersion = 2,
    /// The length prefix exceeds the connection's max-frame guard
    /// (`detail` = the offered length, saturated).
    Oversized = 3,
    /// The request's `k` does not match the serving front's fixed `k`
    /// (`detail` = the `k` this server serves).
    MismatchedK = 4,
    /// The query tile is unusable: zero/mismatched dimensionality or
    /// an empty tile (`detail` = the dimensionality this server
    /// serves, when relevant).
    BadQuery = 5,
    /// The request's `route_top_m` does not match the serving front's
    /// routing configuration (`detail` = the configured fan-out, 0 for
    /// full fan-out).
    MismatchedRoute = 6,
    /// The server is draining and no longer accepts queries.
    ShuttingDown = 7,
}

impl ErrorCode {
    /// Wire byte for this code.
    pub fn as_u8(self) -> u8 {
        self as u8
    }

    /// Decode a wire byte; `None` for codes this build does not know.
    pub fn from_u8(byte: u8) -> Option<Self> {
        match byte {
            1 => Some(Self::Malformed),
            2 => Some(Self::UnsupportedVersion),
            3 => Some(Self::Oversized),
            4 => Some(Self::MismatchedK),
            5 => Some(Self::BadQuery),
            6 => Some(Self::MismatchedRoute),
            7 => Some(Self::ShuttingDown),
            _ => None,
        }
    }
}

impl std::fmt::Display for ErrorCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let name = match self {
            Self::Malformed => "malformed frame",
            Self::UnsupportedVersion => "unsupported protocol version",
            Self::Oversized => "oversized frame",
            Self::MismatchedK => "mismatched k",
            Self::BadQuery => "bad query tile",
            Self::MismatchedRoute => "mismatched route_top_m",
            Self::ShuttingDown => "server shutting down",
        };
        f.write_str(name)
    }
}

/// A batch query request: `count` dense rows of `dim` f32 values plus
/// the per-request search configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryFrame {
    /// Neighbors requested per query.
    pub k: u32,
    /// Centroid-routing fan-out bound; `0` requests the full fan-out.
    pub route_top_m: u32,
    /// Number of query rows in the tile.
    pub count: u32,
    /// Dimensionality of each row.
    pub dim: u32,
    /// End-to-end latency budget in microseconds; `0` means no
    /// deadline. v1 frames have no such field and decode as `0`.
    pub deadline_us: u64,
    /// Row-major `count × dim` tile.
    pub data: Vec<f32>,
}

/// A batch answer: per-query neighbor lists plus the
/// [`WindowInfo`]-style batching diagnostics each query rode with.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultsFrame {
    /// The `k` the answers were computed for.
    pub k: u32,
    /// Per-query neighbors, ascending by (distance, original id).
    pub results: Vec<Vec<Neighbor>>,
    /// Per-query window diagnostics (same order as `results`).
    pub windows: Vec<WindowInfo>,
}

/// A degraded batch answer: the honest merge over the shards that did
/// answer, plus the typed record of what went missing and why.
#[derive(Debug, Clone, PartialEq)]
pub struct DegradedFrame {
    /// The partial answers (same layout as a full [`ResultsFrame`]).
    pub results: ResultsFrame,
    /// Slice-order shard indices absent from the merge, ascending.
    pub shards_missing: Vec<u32>,
    /// Replicas consulted per missing shard (parallel to
    /// `shards_missing`). `0` means the shard was never dispatchable;
    /// v2 frames decode as all zeros ("not reported").
    pub replicas_tried: Vec<u32>,
    /// The most severe reason anything went missing.
    pub cause: DegradeCause,
}

impl DegradedFrame {
    /// The api-level degradation record this frame carries.
    pub fn degradation(&self) -> Degradation {
        Degradation {
            shards_missing: self.shards_missing.clone(),
            replicas_tried: self.replicas_tried.clone(),
            cause: self.cause,
        }
    }
}

/// A health snapshot reply: per-shard liveness plus the pool's fault
/// counters (zeros and an empty shard list over a server without a
/// supervised pool).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthFrame {
    /// The token from the health probe being answered.
    pub token: u64,
    /// Worker threads in the serving pool (0 = no pool).
    pub threads: u32,
    /// Workers respawned after dying.
    pub respawns: u64,
    /// Shard-search panics contained.
    pub contained_panics: u64,
    /// Replies lost from live workers.
    pub lost_replies: u64,
    /// Shards dropped by expired deadlines.
    pub deadline_misses: u64,
    /// Per-shard liveness, slice order (`true` = at least one replica
    /// serving).
    pub shards_alive: Vec<bool>,
    /// Replica sets per shard (1 = unreplicated; v2 frames decode
    /// as 1).
    pub replicas: u32,
    /// Hedged re-dispatches fired at stragglers (v3+; v2 decodes 0).
    pub hedges_sent: u64,
    /// Hedged re-dispatches whose reply won (v3+; v2 decodes 0).
    pub hedge_wins: u64,
    /// Dispatches that fell over to a non-primary replica (v3+; v2
    /// decodes 0).
    pub failovers: u64,
    /// Per-replica liveness, shard-major (`shards × replicas` entries:
    /// replica `r` of shard `s` at `s * replicas + r`). v2 frames
    /// decode with a copy of `shards_alive` (one replica per shard).
    pub replicas_alive: Vec<bool>,
}

/// A typed error reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ErrorFrame {
    /// What went wrong.
    pub code: ErrorCode,
    /// Code-specific detail value (see [`ErrorCode`] docs).
    pub detail: u32,
    /// Human-readable context (bounded at `u16::MAX` bytes on the wire).
    pub message: String,
}

/// One decoded `KNNQv1` frame.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Liveness/metadata probe carrying an echo token.
    Ping {
        /// Echo token the server must return in its [`Frame::Pong`].
        token: u64,
    },
    /// Reply to [`Frame::Ping`]: echoed token plus corpus shape.
    Pong {
        /// The token from the ping being answered.
        token: u64,
        /// Rows in the served corpus.
        n: u64,
        /// Query dimensionality the server expects.
        dim: u32,
        /// The fixed `k` the server serves.
        k: u32,
    },
    /// A batch query request.
    Query(QueryFrame),
    /// A batch answer.
    Results(ResultsFrame),
    /// A typed error reply.
    Error(ErrorFrame),
    /// Graceful-shutdown request (client → server) or acknowledgement
    /// (server → client, sent before the server drains and exits).
    Shutdown,
    /// A degraded batch answer (shards dropped by a deadline or a dead
    /// worker). v2+.
    Degraded(DegradedFrame),
    /// Liveness/health probe carrying an echo token. v2+.
    Health {
        /// Echo token the server must return in its
        /// [`Frame::HealthReply`].
        token: u64,
    },
    /// Reply to [`Frame::Health`]. v2+.
    HealthReply(HealthFrame),
    /// Insert (or overwrite) one row in a mutable store. v2+.
    Insert {
        /// External id of the row.
        id: u32,
        /// The row, logical (unpadded) dimensionality.
        row: Vec<f32>,
    },
    /// Delete one external id from a mutable store. v2+.
    Delete {
        /// External id to delete.
        id: u32,
    },
    /// Request a manual compaction of a mutable store. v2+.
    Compact,
    /// Acknowledge a mutation. v2+.
    MutateOk {
        /// Which mutation this acknowledges ([`MUTATE_OP_INSERT`] /
        /// [`MUTATE_OP_DELETE`] / [`MUTATE_OP_COMPACT`]).
        op: u8,
        /// Whether the mutation changed anything (a delete of an
        /// absent id acknowledges with `false`).
        applied: bool,
        /// The store's compaction generation after the mutation.
        generation: u64,
        /// Live rows in the store after the mutation.
        live: u64,
    },
}

/// Wire kind byte of a query frame — the one kind the server decodes
/// zero-copy (see [`decode_query_view`]), so it gets a name.
pub const KIND_QUERY: u8 = 3;

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Self::Ping { .. } => 1,
            Self::Pong { .. } => 2,
            Self::Query(_) => KIND_QUERY,
            Self::Results(_) => 4,
            Self::Error(_) => 5,
            Self::Shutdown => 6,
            Self::Degraded(_) => 7,
            Self::Health { .. } => 8,
            Self::HealthReply(_) => 9,
            Self::Insert { .. } => 10,
            Self::Delete { .. } => 11,
            Self::Compact => 12,
            Self::MutateOk { .. } => 13,
        }
    }
}

/// Why a frame could not be read/decoded.
#[derive(Debug)]
pub enum WireError {
    /// The peer closed the stream cleanly between frames (zero bytes
    /// where the next length prefix would start). Not an error for a
    /// server: the client simply hung up.
    Eof,
    /// The transport failed mid-frame (includes torn frames —
    /// `UnexpectedEof` inside a payload — and read timeouts).
    Io(std::io::Error),
    /// The bytes arrived but do not form a valid frame.
    Protocol {
        /// The typed code a server should answer with.
        code: ErrorCode,
        /// Code-specific detail (see [`ErrorCode`]).
        detail: u32,
        /// Human-readable context.
        message: String,
        /// True when the length prefix itself was untrustworthy, so
        /// the stream can no longer be framed and the connection must
        /// close. False means exactly `len` payload bytes were
        /// consumed: the stream is still in sync and the connection
        /// can reply with an error frame and keep serving.
        desync: bool,
    },
}

impl WireError {
    fn malformed(message: impl Into<String>) -> Self {
        Self::Protocol {
            code: ErrorCode::Malformed,
            detail: 0,
            message: message.into(),
            desync: false,
        }
    }
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::Eof => f.write_str("peer closed the connection"),
            Self::Io(e) => write!(f, "wire i/o error: {e}"),
            Self::Protocol { code, detail, message, desync } => {
                let tail = if *desync { " [desync]" } else { "" };
                write!(f, "{code} (detail {detail}): {message}{tail}")
            }
        }
    }
}

impl std::error::Error for WireError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        Self::Io(e)
    }
}

/// Encode `frame` and write it (length prefix + payload) to `w`. The
/// writer is not flushed — callers batching multiple frames flush once.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> std::io::Result<()> {
    let mut payload = Vec::with_capacity(64);
    payload.extend_from_slice(MAGIC);
    payload.push(VERSION);
    payload.push(frame.kind());
    payload.extend_from_slice(&0u16.to_le_bytes()); // flags: must be 0 in v1
    encode_body(&mut payload, frame);
    let mut crc = Fnv::new();
    crc.update(&payload);
    payload.extend_from_slice(&crc.0.to_le_bytes());
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&payload)
}

fn encode_body(buf: &mut Vec<u8>, frame: &Frame) {
    match frame {
        Frame::Ping { token } => buf.extend_from_slice(&token.to_le_bytes()),
        Frame::Pong { token, n, dim, k } => {
            buf.extend_from_slice(&token.to_le_bytes());
            buf.extend_from_slice(&n.to_le_bytes());
            buf.extend_from_slice(&dim.to_le_bytes());
            buf.extend_from_slice(&k.to_le_bytes());
        }
        Frame::Query(q) => {
            buf.extend_from_slice(&q.k.to_le_bytes());
            buf.extend_from_slice(&q.route_top_m.to_le_bytes());
            buf.extend_from_slice(&q.count.to_le_bytes());
            buf.extend_from_slice(&q.dim.to_le_bytes());
            buf.extend_from_slice(&q.deadline_us.to_le_bytes());
            for &x in &q.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Results(r) => encode_results(buf, r),
        Frame::Degraded(d) => {
            buf.push(d.cause.as_u8());
            buf.extend_from_slice(&(d.shards_missing.len() as u32).to_le_bytes());
            for &s in &d.shards_missing {
                buf.extend_from_slice(&s.to_le_bytes());
            }
            // v3: replicas tried, parallel to the missing list
            for &r in &d.replicas_tried {
                buf.extend_from_slice(&r.to_le_bytes());
            }
            encode_results(buf, &d.results);
        }
        Frame::Health { token } => buf.extend_from_slice(&token.to_le_bytes()),
        Frame::HealthReply(h) => {
            buf.extend_from_slice(&h.token.to_le_bytes());
            buf.extend_from_slice(&h.threads.to_le_bytes());
            buf.extend_from_slice(&h.respawns.to_le_bytes());
            buf.extend_from_slice(&h.contained_panics.to_le_bytes());
            buf.extend_from_slice(&h.lost_replies.to_le_bytes());
            buf.extend_from_slice(&h.deadline_misses.to_le_bytes());
            buf.extend_from_slice(&(h.shards_alive.len() as u32).to_le_bytes());
            for &alive in &h.shards_alive {
                buf.push(alive as u8);
            }
            // v3: replication snapshot
            buf.extend_from_slice(&h.replicas.to_le_bytes());
            buf.extend_from_slice(&h.hedges_sent.to_le_bytes());
            buf.extend_from_slice(&h.hedge_wins.to_le_bytes());
            buf.extend_from_slice(&h.failovers.to_le_bytes());
            buf.extend_from_slice(&(h.replicas_alive.len() as u32).to_le_bytes());
            for &alive in &h.replicas_alive {
                buf.push(alive as u8);
            }
        }
        Frame::Error(e) => {
            buf.push(e.code.as_u8());
            buf.extend_from_slice(&e.detail.to_le_bytes());
            let msg = e.message.as_bytes();
            let take = msg.len().min(u16::MAX as usize);
            buf.extend_from_slice(&(take as u16).to_le_bytes());
            buf.extend_from_slice(&msg[..take]);
        }
        Frame::Insert { id, row } => {
            buf.extend_from_slice(&id.to_le_bytes());
            buf.extend_from_slice(&(row.len() as u32).to_le_bytes());
            for &x in row {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        Frame::Delete { id } => buf.extend_from_slice(&id.to_le_bytes()),
        Frame::MutateOk { op, applied, generation, live } => {
            buf.push(*op);
            buf.push(*applied as u8);
            buf.extend_from_slice(&generation.to_le_bytes());
            buf.extend_from_slice(&live.to_le_bytes());
        }
        Frame::Shutdown | Frame::Compact => {}
    }
}

/// Shared body layout of [`Frame::Results`] and the results section of
/// [`Frame::Degraded`].
fn encode_results(buf: &mut Vec<u8>, r: &ResultsFrame) {
    buf.extend_from_slice(&(r.results.len() as u32).to_le_bytes());
    buf.extend_from_slice(&r.k.to_le_bytes());
    for hits in &r.results {
        buf.extend_from_slice(&(hits.len() as u32).to_le_bytes());
        for h in hits {
            buf.extend_from_slice(&h.id.0.to_le_bytes());
            buf.extend_from_slice(&h.dist.to_le_bytes());
        }
    }
    for wnd in &r.windows {
        buf.extend_from_slice(&(wnd.requests as u32).to_le_bytes());
        buf.extend_from_slice(&(wnd.unique as u32).to_le_bytes());
        buf.push(wnd.coalesced as u8);
    }
}

/// Read one length-prefixed payload from `r` without decoding it,
/// enforcing `max_frame` on the length prefix before reading. This is
/// the transport half of [`read_frame`]; pair it with
/// [`decode_payload`] (owning decode) or [`decode_query_view`]
/// (zero-copy query decode straight out of this buffer).
pub fn read_payload<R: Read>(r: &mut R, max_frame: usize) -> Result<Vec<u8>, WireError> {
    let mut len_buf = [0u8; 4];
    // the first byte distinguishes a clean hang-up (Eof) from a frame
    // torn mid-way (Io(UnexpectedEof))
    let first = loop {
        match r.read(&mut len_buf[..1]) {
            Ok(n) => break n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    };
    if first == 0 {
        return Err(WireError::Eof);
    }
    r.read_exact(&mut len_buf[1..])?;
    let len = u32::from_le_bytes(len_buf) as usize;
    if len < MIN_PAYLOAD {
        return Err(WireError::Protocol {
            code: ErrorCode::Malformed,
            detail: len as u32,
            message: format!("payload length {len} below minimum {MIN_PAYLOAD}"),
            desync: true,
        });
    }
    if len > max_frame {
        return Err(WireError::Protocol {
            code: ErrorCode::Oversized,
            detail: len as u32,
            message: format!("payload length {len} exceeds max frame {max_frame}"),
            desync: true,
        });
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Read and decode one frame from `r`, enforcing `max_frame` on the
/// length prefix before reading the payload. Never panics on wire
/// input; see [`WireError`] for the failure taxonomy.
pub fn read_frame<R: Read>(r: &mut R, max_frame: usize) -> Result<Frame, WireError> {
    let payload = read_payload(r, max_frame)?;
    decode_payload(&payload)
}

/// The frame-kind byte of a complete payload, for routing a buffer to
/// the right decoder before committing to a full decode. `None` when
/// the buffer is too short to carry one (the decoders reject it
/// properly).
pub fn payload_kind(payload: &[u8]) -> Option<u8> {
    (payload.len() >= MIN_PAYLOAD).then(|| payload[5])
}

/// Validate everything about a payload except its body: length floor,
/// magic, version range, CRC, zero flags. Returns (version, kind,
/// body bytes).
fn validate_envelope(payload: &[u8]) -> Result<(u8, u8, &[u8]), WireError> {
    if payload.len() < MIN_PAYLOAD {
        return Err(WireError::malformed("payload below minimum length"));
    }
    let body_end = payload.len() - 8;
    let mut crc = Fnv::new();
    crc.update(&payload[..body_end]);
    let mut tail = [0u8; 8];
    tail.copy_from_slice(&payload[body_end..]);
    if &payload[..4] != MAGIC {
        return Err(WireError::malformed("bad magic"));
    }
    let version = payload[4];
    if !(LEGACY_VERSION..=VERSION).contains(&version) {
        return Err(WireError::Protocol {
            code: ErrorCode::UnsupportedVersion,
            detail: version as u32,
            message: format!(
                "version {version} not supported (this build speaks {LEGACY_VERSION}..={VERSION})"
            ),
            desync: false,
        });
    }
    if u64::from_le_bytes(tail) != crc.0 {
        return Err(WireError::malformed("checksum mismatch"));
    }
    let flags = u16::from_le_bytes([payload[6], payload[7]]);
    if flags != 0 {
        return Err(WireError::malformed(format!("unknown flags {flags:#06x}")));
    }
    Ok((version, payload[5], &payload[8..body_end]))
}

/// Decode a complete payload (everything after the length prefix).
/// All failures are in-sync protocol errors: the caller already
/// consumed exactly the prefixed length.
pub fn decode_payload(payload: &[u8]) -> Result<Frame, WireError> {
    let (version, kind, body) = validate_envelope(payload)?;
    let mut dec = Dec { buf: body, pos: 0 };
    let frame = decode_body(version, kind, &mut dec)?;
    dec.done()?;
    Ok(frame)
}

/// A query frame decoded **in place**: the fixed fields are parsed,
/// the `count × dim` f32 tile stays as borrowed little-endian bytes in
/// the frame buffer. [`row_into`](QueryView::row_into) converts one
/// row at a time directly into its padded destination, so the serving
/// path does one decode pass with no intermediate `Vec<f32>`.
#[derive(Debug)]
pub struct QueryView<'a> {
    /// Neighbors requested per query.
    pub k: u32,
    /// Centroid-routing fan-out bound; `0` requests the full fan-out.
    pub route_top_m: u32,
    /// Number of query rows in the tile.
    pub count: u32,
    /// Dimensionality of each row.
    pub dim: u32,
    /// End-to-end latency budget in microseconds; `0` = none.
    pub deadline_us: u64,
    /// Raw little-endian tile bytes, exactly `count · dim · 4`.
    data: &'a [u8],
}

impl QueryView<'_> {
    /// Decode row `q` into `out[..dim]` (any tail of `out` is left
    /// untouched — pass a padded row and keep its zero tail).
    #[inline]
    pub fn row_into(&self, q: usize, out: &mut [f32]) {
        let dim = self.dim as usize;
        debug_assert!(q < self.count as usize && out.len() >= dim);
        let bytes = &self.data[q * dim * 4..(q + 1) * dim * 4];
        for (dst, src) in out[..dim].iter_mut().zip(bytes.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
    }

    /// Materialize the owning [`QueryFrame`] (compatibility path; the
    /// bit patterns are identical to what [`decode_payload`] builds).
    pub fn to_query_frame(&self) -> QueryFrame {
        let mut data = vec![0.0f32; self.count as usize * self.dim as usize];
        for (dst, src) in data.iter_mut().zip(self.data.chunks_exact(4)) {
            *dst = f32::from_le_bytes(src.try_into().unwrap());
        }
        QueryFrame {
            k: self.k,
            route_top_m: self.route_top_m,
            count: self.count,
            dim: self.dim,
            deadline_us: self.deadline_us,
            data,
        }
    }
}

/// Zero-copy decode of a query payload: full envelope validation
/// (magic, version, CRC, flags) and fixed-field parsing, with the
/// query tile left borrowed in place. Fails exactly where
/// [`decode_payload`] would — including on non-query kinds — so the
/// two decoders accept and reject identical byte strings.
pub fn decode_query_view(payload: &[u8]) -> Result<QueryView<'_>, WireError> {
    let (version, kind, body) = validate_envelope(payload)?;
    if kind != KIND_QUERY {
        return Err(WireError::malformed(format!("expected a query frame, got kind {kind}")));
    }
    let mut dec = Dec { buf: body, pos: 0 };
    let (k, route_top_m) = (dec.u32()?, dec.u32()?);
    let (count, dim) = (dec.u32()?, dec.u32()?);
    let deadline_us = if version >= 2 { dec.u64()? } else { 0 };
    let cells = match (count as usize).checked_mul(dim as usize) {
        Some(c) if c.checked_mul(4) == Some(dec.remaining()) => c,
        _ => {
            return Err(WireError::malformed("query tile byte count does not match count × dim"));
        }
    };
    let data = dec.take(cells * 4)?;
    dec.done()?;
    Ok(QueryView { k, route_top_m, count, dim, deadline_us, data })
}

fn decode_body(version: u8, kind: u8, dec: &mut Dec<'_>) -> Result<Frame, WireError> {
    match kind {
        1 => Ok(Frame::Ping { token: dec.u64()? }),
        2 => Ok(Frame::Pong { token: dec.u64()?, n: dec.u64()?, dim: dec.u32()?, k: dec.u32()? }),
        3 => {
            let (k, route_top_m) = (dec.u32()?, dec.u32()?);
            let (count, dim) = (dec.u32()?, dec.u32()?);
            // v1 queries have no deadline field: decode as "no deadline"
            let deadline_us = if version >= 2 { dec.u64()? } else { 0 };
            let cells = match (count as usize).checked_mul(dim as usize) {
                Some(c) if c.checked_mul(4) == Some(dec.remaining()) => c,
                _ => {
                    let msg = "query tile byte count does not match count × dim";
                    return Err(WireError::malformed(msg));
                }
            };
            let mut data = Vec::with_capacity(cells);
            for _ in 0..cells {
                data.push(dec.f32()?);
            }
            Ok(Frame::Query(QueryFrame { k, route_top_m, count, dim, deadline_us, data }))
        }
        4 => Ok(Frame::Results(decode_results(dec)?)),
        7 => {
            let cause_byte = dec.u8()?;
            let Some(cause) = DegradeCause::from_u8(cause_byte) else {
                return Err(WireError::malformed(format!(
                    "unknown degradation cause {cause_byte}"
                )));
            };
            let missing = dec.u32()? as usize;
            if missing > dec.remaining() / 4 {
                return Err(WireError::malformed("missing-shard count exceeds frame body"));
            }
            let mut shards_missing = Vec::with_capacity(missing);
            for _ in 0..missing {
                shards_missing.push(dec.u32()?);
            }
            // v2 frames carry no replicas-tried list: decode as zeros
            // ("not reported"), one per missing shard
            let mut replicas_tried = vec![0u32; missing];
            if version >= 3 {
                if missing > dec.remaining() / 4 {
                    return Err(WireError::malformed(
                        "replicas-tried list exceeds frame body",
                    ));
                }
                for slot in replicas_tried.iter_mut() {
                    *slot = dec.u32()?;
                }
            }
            let results = decode_results(dec)?;
            Ok(Frame::Degraded(DegradedFrame { results, shards_missing, replicas_tried, cause }))
        }
        8 => Ok(Frame::Health { token: dec.u64()? }),
        9 => {
            let token = dec.u64()?;
            let threads = dec.u32()?;
            let respawns = dec.u64()?;
            let contained_panics = dec.u64()?;
            let lost_replies = dec.u64()?;
            let deadline_misses = dec.u64()?;
            let shards = dec.u32()? as usize;
            if shards > dec.remaining() {
                return Err(WireError::malformed("shard count exceeds frame body"));
            }
            let mut shards_alive = Vec::with_capacity(shards);
            for _ in 0..shards {
                shards_alive.push(dec.u8()? != 0);
            }
            // v2 frames predate replication: decode as an unreplicated
            // pool whose replica liveness mirrors the shard liveness
            let (replicas, hedges_sent, hedge_wins, failovers, replicas_alive);
            if version >= 3 {
                replicas = dec.u32()?;
                hedges_sent = dec.u64()?;
                hedge_wins = dec.u64()?;
                failovers = dec.u64()?;
                let rcount = dec.u32()? as usize;
                if rcount > dec.remaining() {
                    return Err(WireError::malformed("replica count exceeds frame body"));
                }
                let mut alive = Vec::with_capacity(rcount);
                for _ in 0..rcount {
                    alive.push(dec.u8()? != 0);
                }
                replicas_alive = alive;
            } else {
                replicas = 1;
                hedges_sent = 0;
                hedge_wins = 0;
                failovers = 0;
                replicas_alive = shards_alive.clone();
            }
            Ok(Frame::HealthReply(HealthFrame {
                token,
                threads,
                respawns,
                contained_panics,
                lost_replies,
                deadline_misses,
                shards_alive,
                replicas,
                hedges_sent,
                hedge_wins,
                failovers,
                replicas_alive,
            }))
        }
        5 => {
            let code_byte = dec.u8()?;
            let code = match ErrorCode::from_u8(code_byte) {
                Some(c) => c,
                None => return Err(WireError::malformed(format!("unknown error code {code_byte}"))),
            };
            let detail = dec.u32()?;
            let msg_len = dec.u16()? as usize;
            let message = String::from_utf8_lossy(dec.take(msg_len)?).into_owned();
            Ok(Frame::Error(ErrorFrame { code, detail, message }))
        }
        6 => Ok(Frame::Shutdown),
        10 => {
            require_v2(version, kind)?;
            let id = dec.u32()?;
            let dim = dec.u32()? as usize;
            if dim.checked_mul(4) != Some(dec.remaining()) {
                return Err(WireError::malformed("insert row byte count does not match dim"));
            }
            let mut row = Vec::with_capacity(dim);
            for _ in 0..dim {
                row.push(dec.f32()?);
            }
            Ok(Frame::Insert { id, row })
        }
        11 => {
            require_v2(version, kind)?;
            Ok(Frame::Delete { id: dec.u32()? })
        }
        12 => {
            require_v2(version, kind)?;
            Ok(Frame::Compact)
        }
        13 => {
            require_v2(version, kind)?;
            let op = dec.u8()?;
            if !matches!(op, MUTATE_OP_INSERT | MUTATE_OP_DELETE | MUTATE_OP_COMPACT) {
                return Err(WireError::malformed(format!("unknown mutation op {op}")));
            }
            let applied_byte = dec.u8()?;
            if applied_byte > 1 {
                return Err(WireError::malformed(format!(
                    "mutation applied byte must be 0 or 1, got {applied_byte}"
                )));
            }
            Ok(Frame::MutateOk {
                op,
                applied: applied_byte == 1,
                generation: dec.u64()?,
                live: dec.u64()?,
            })
        }
        other => Err(WireError::malformed(format!("unknown frame kind {other}"))),
    }
}

/// The mutation kinds are v2-only: a v1 peer never sent one on
/// purpose, so treat it as malformed rather than guessing.
fn require_v2(version: u8, kind: u8) -> Result<(), WireError> {
    if version >= 2 {
        Ok(())
    } else {
        Err(WireError::malformed(format!("frame kind {kind} requires protocol version 2")))
    }
}

/// Shared decode of the [`Frame::Results`] body layout (also the tail
/// of a [`Frame::Degraded`] body).
fn decode_results(dec: &mut Dec<'_>) -> Result<ResultsFrame, WireError> {
    let count = dec.u32()? as usize;
    let k = dec.u32()?;
    let mut results = Vec::new();
    for _ in 0..count {
        let cnt = dec.u32()? as usize;
        if cnt > dec.remaining() / 8 {
            return Err(WireError::malformed("neighbor count exceeds frame body"));
        }
        let mut hits = Vec::with_capacity(cnt);
        for _ in 0..cnt {
            hits.push(Neighbor::new(dec.u32()?, dec.f32()?));
        }
        results.push(hits);
    }
    let mut windows = Vec::with_capacity(count);
    for _ in 0..count {
        windows.push(WindowInfo {
            requests: dec.u32()? as usize,
            unique: dec.u32()? as usize,
            coalesced: dec.u8()? != 0,
        });
    }
    Ok(ResultsFrame { k, results, windows })
}

/// Bounds-checked little-endian cursor over a frame body; every
/// overrun is a typed error, never a panic.
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        match self.pos.checked_add(n) {
            Some(end) if end <= self.buf.len() => {
                let slice = &self.buf[self.pos..end];
                self.pos = end;
                Ok(slice)
            }
            _ => Err(WireError::malformed("frame body shorter than its declared contents")),
        }
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, WireError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        let b = self.take(8)?;
        let mut arr = [0u8; 8];
        arr.copy_from_slice(b);
        Ok(u64::from_le_bytes(arr))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn done(&self) -> Result<(), WireError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            let msg = format!("{} trailing bytes after frame body", self.remaining());
            Err(WireError::malformed(msg))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME).unwrap()
    }

    #[test]
    fn ping_pong_shutdown_round_trip() {
        let ping = Frame::Ping { token: 0xDEAD_BEEF_1234_5678 };
        assert_eq!(round_trip(&ping), ping);
        let pong = Frame::Pong { token: 7, n: 1_000_000, dim: 128, k: 10 };
        assert_eq!(round_trip(&pong), pong);
        assert_eq!(round_trip(&Frame::Shutdown), Frame::Shutdown);
    }

    #[test]
    fn query_round_trip_preserves_f32_bits() {
        let weird = f32::from_bits(0x7FC0_1234); // NaN with a payload
        let q = Frame::Query(QueryFrame {
            k: 10,
            route_top_m: 0,
            count: 2,
            dim: 3,
            deadline_us: 2_500,
            data: vec![1.0, -0.0, weird, f32::INFINITY, f32::MIN_POSITIVE, -2.5],
        });
        let Frame::Query(back) = round_trip(&q) else { panic!("wrong kind back") };
        let Frame::Query(orig) = q else { unreachable!() };
        let orig_bits: Vec<u32> = orig.data.iter().map(|x| x.to_bits()).collect();
        let back_bits: Vec<u32> = back.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(orig_bits, back_bits, "f32 bit patterns must survive the wire");
    }

    #[test]
    fn results_and_error_round_trip() {
        let r = Frame::Results(ResultsFrame {
            k: 2,
            results: vec![
                vec![Neighbor::new(3, 0.25), Neighbor::new(9, 1.5)],
                vec![Neighbor::new(1, 0.0)],
            ],
            windows: vec![
                WindowInfo { requests: 4, unique: 3, coalesced: true },
                WindowInfo { requests: 4, unique: 3, coalesced: false },
            ],
        });
        assert_eq!(round_trip(&r), r);
        let e = Frame::Error(ErrorFrame {
            code: ErrorCode::MismatchedK,
            detail: 10,
            message: "requested k=5 but this server serves k=10".into(),
        });
        assert_eq!(round_trip(&e), e);
    }

    #[test]
    fn empty_query_tile_round_trips() {
        let q = Frame::Query(QueryFrame {
            k: 1,
            route_top_m: 0,
            count: 0,
            dim: 8,
            deadline_us: 0,
            data: vec![],
        });
        assert_eq!(round_trip(&q), q);
    }

    #[test]
    fn corrupted_crc_is_in_sync_protocol_error() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ping { token: 1 }).unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0xFF; // flip a crc byte
        match read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME) {
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. }) => {}
            other => panic!("expected in-sync Malformed, got {other:?}"),
        }
    }

    #[test]
    fn corrupted_body_is_caught_by_crc() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ping { token: 42 }).unwrap();
        buf[12] ^= 0x01; // flip a body byte, leaving the crc stale
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
        ));
    }

    #[test]
    fn wrong_magic_and_version_are_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        let mut bad_magic = buf.clone();
        bad_magic[4] = b'X'; // first magic byte (after the 4 B length prefix)
        assert!(matches!(
            read_frame(&mut Cursor::new(bad_magic), DEFAULT_MAX_FRAME),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
        ));
        let mut bad_version = buf;
        bad_version[8] = 9; // version byte
        match read_frame(&mut Cursor::new(bad_version), DEFAULT_MAX_FRAME) {
            Err(WireError::Protocol { code: ErrorCode::UnsupportedVersion, detail: 9, .. }) => {}
            other => panic!("expected UnsupportedVersion(9), got {other:?}"),
        }
    }

    #[test]
    fn oversized_and_undersized_lengths_desync() {
        let huge = u32::MAX.to_le_bytes().to_vec();
        match read_frame(&mut Cursor::new(huge), DEFAULT_MAX_FRAME) {
            Err(WireError::Protocol { code: ErrorCode::Oversized, desync: true, .. }) => {}
            other => panic!("expected desync Oversized, got {other:?}"),
        }
        let tiny = 3u32.to_le_bytes().to_vec();
        assert!(matches!(
            read_frame(&mut Cursor::new(tiny), DEFAULT_MAX_FRAME),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: true, .. })
        ));
    }

    #[test]
    fn truncation_and_clean_eof_are_distinguished() {
        assert!(matches!(read_frame(&mut Cursor::new(Vec::new()), 1024), Err(WireError::Eof)));
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Ping { token: 3 }).unwrap();
        buf.truncate(buf.len() - 5); // tear the frame mid-payload
        assert!(matches!(read_frame(&mut Cursor::new(buf), 1024), Err(WireError::Io(_))));
    }

    #[test]
    fn query_byte_count_mismatch_is_malformed() {
        // hand-build a query frame claiming 2×3 floats but carrying 5
        let mut payload = Vec::new();
        payload.extend_from_slice(MAGIC);
        payload.push(VERSION);
        payload.push(3); // kind: Query
        payload.extend_from_slice(&0u16.to_le_bytes());
        for v in [10u32, 0, 2, 3] {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        payload.extend_from_slice(&0u64.to_le_bytes()); // deadline_us
        for _ in 0..5 {
            payload.extend_from_slice(&1.0f32.to_le_bytes());
        }
        let mut crc = Fnv::new();
        crc.update(&payload);
        payload.extend_from_slice(&crc.0.to_le_bytes());
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&payload);
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
        ));
    }

    #[test]
    fn nonzero_flags_and_unknown_kind_are_malformed() {
        // payload offsets: 4 = version, 5 = kind, 6..8 = flags
        for (offset, value) in [(6usize, 1u8), (5, 200)] {
            let mut payload = Vec::new();
            payload.extend_from_slice(MAGIC);
            payload.push(VERSION);
            payload.push(6); // kind: Shutdown
            payload.extend_from_slice(&0u16.to_le_bytes());
            payload[offset] = value;
            let mut crc = Fnv::new();
            crc.update(&payload);
            payload.extend_from_slice(&crc.0.to_le_bytes());
            let mut framed = (payload.len() as u32).to_le_bytes().to_vec();
            framed.extend_from_slice(&payload);
            assert!(matches!(
                read_frame(&mut Cursor::new(framed), DEFAULT_MAX_FRAME),
                Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
            ));
        }
    }

    #[test]
    fn legacy_v1_query_decodes_as_no_deadline() {
        // hand-build a version-1 query frame: no deadline_us field
        let mut payload = Vec::new();
        payload.extend_from_slice(MAGIC);
        payload.push(LEGACY_VERSION);
        payload.push(3); // kind: Query
        payload.extend_from_slice(&0u16.to_le_bytes());
        for v in [7u32, 2, 1, 3] {
            payload.extend_from_slice(&v.to_le_bytes());
        }
        for x in [1.5f32, -0.0, 3.25] {
            payload.extend_from_slice(&x.to_le_bytes());
        }
        let mut crc = Fnv::new();
        crc.update(&payload);
        payload.extend_from_slice(&crc.0.to_le_bytes());
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(&payload);
        let Frame::Query(q) = read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME).unwrap()
        else {
            panic!("expected a query frame back");
        };
        assert_eq!((q.k, q.route_top_m, q.count, q.dim), (7, 2, 1, 3));
        assert_eq!(q.deadline_us, 0, "legacy frames mean 'no deadline'");
        assert_eq!(q.data.len(), 3);
        assert_eq!(q.data[1].to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn degraded_and_health_frames_round_trip() {
        let d = Frame::Degraded(DegradedFrame {
            results: ResultsFrame {
                k: 2,
                results: vec![vec![Neighbor::new(5, 0.5)], vec![]],
                windows: vec![
                    WindowInfo { requests: 2, unique: 2, coalesced: false },
                    WindowInfo { requests: 2, unique: 2, coalesced: false },
                ],
            },
            shards_missing: vec![1, 3],
            replicas_tried: vec![2, 1],
            cause: DegradeCause::DeadlineExpired,
        });
        assert_eq!(round_trip(&d), d);
        let Frame::Degraded(df) = d else { unreachable!() };
        assert_eq!(df.degradation().shards_missing, vec![1, 3]);
        assert_eq!(df.degradation().replicas_tried, vec![2, 1]);

        let probe = Frame::Health { token: 99 };
        assert_eq!(round_trip(&probe), probe);
        let h = Frame::HealthReply(HealthFrame {
            token: 99,
            threads: 4,
            respawns: 2,
            contained_panics: 7,
            lost_replies: 1,
            deadline_misses: 12,
            shards_alive: vec![true, false, true, true],
            replicas: 2,
            hedges_sent: 9,
            hedge_wins: 3,
            failovers: 5,
            replicas_alive: vec![true, true, false, false, true, false, true, true],
        });
        assert_eq!(round_trip(&h), h);
        // empty shard list (no pool behind the server) is legal
        let none = Frame::HealthReply(HealthFrame {
            token: 1,
            threads: 0,
            respawns: 0,
            contained_panics: 0,
            lost_replies: 0,
            deadline_misses: 0,
            shards_alive: vec![],
            replicas: 1,
            hedges_sent: 0,
            hedge_wins: 0,
            failovers: 0,
            replicas_alive: vec![],
        });
        assert_eq!(round_trip(&none), none);
    }

    #[test]
    fn legacy_v2_degraded_and_health_decode_with_replication_defaults() {
        // hand-build a v2 Degraded payload: no replicas-tried list
        let mut payload = Vec::new();
        payload.extend_from_slice(MAGIC);
        payload.push(2); // version 2
        payload.push(7); // kind: Degraded
        payload.extend_from_slice(&0u16.to_le_bytes());
        payload.push(DegradeCause::ShardDead.as_u8());
        payload.extend_from_slice(&2u32.to_le_bytes()); // missing count
        payload.extend_from_slice(&0u32.to_le_bytes()); // shard 0
        payload.extend_from_slice(&2u32.to_le_bytes()); // shard 2
        payload.extend_from_slice(&0u32.to_le_bytes()); // results: count 0
        payload.extend_from_slice(&1u32.to_le_bytes()); // results: k 1
        let mut crc = Fnv::new();
        crc.update(&payload);
        payload.extend_from_slice(&crc.0.to_le_bytes());
        let Frame::Degraded(d) = decode_payload(&payload).unwrap() else {
            panic!("expected a degraded frame back");
        };
        assert_eq!(d.shards_missing, vec![0, 2]);
        assert_eq!(d.replicas_tried, vec![0, 0], "v2 frames report no replica counts");
        assert_eq!(d.cause, DegradeCause::ShardDead);

        // hand-build a v2 HealthReply payload: no replication snapshot
        let mut payload = Vec::new();
        payload.extend_from_slice(MAGIC);
        payload.push(2); // version 2
        payload.push(9); // kind: HealthReply
        payload.extend_from_slice(&0u16.to_le_bytes());
        payload.extend_from_slice(&42u64.to_le_bytes()); // token
        payload.extend_from_slice(&3u32.to_le_bytes()); // threads
        for counter in [1u64, 0, 2, 4] {
            payload.extend_from_slice(&counter.to_le_bytes());
        }
        payload.extend_from_slice(&3u32.to_le_bytes()); // shards
        payload.extend_from_slice(&[1u8, 0, 1]);
        let mut crc = Fnv::new();
        crc.update(&payload);
        payload.extend_from_slice(&crc.0.to_le_bytes());
        let Frame::HealthReply(h) = decode_payload(&payload).unwrap() else {
            panic!("expected a health reply back");
        };
        assert_eq!(h.token, 42);
        assert_eq!(h.shards_alive, vec![true, false, true]);
        assert_eq!(h.replicas, 1, "v2 pools are unreplicated");
        assert_eq!((h.hedges_sent, h.hedge_wins, h.failovers), (0, 0, 0));
        assert_eq!(h.replicas_alive, h.shards_alive, "v2 replica liveness mirrors shards");
    }

    #[test]
    fn unknown_degradation_cause_is_malformed() {
        let mut buf = Vec::new();
        let d = Frame::Degraded(DegradedFrame {
            results: ResultsFrame { k: 1, results: vec![], windows: vec![] },
            shards_missing: vec![0],
            replicas_tried: vec![1],
            cause: DegradeCause::ShardDead,
        });
        write_frame(&mut buf, &d).unwrap();
        // the cause byte is the first body byte: 4 B len + 8 B header
        buf[12] = 200;
        // re-seal the crc so only the cause byte is at fault
        let payload_end = buf.len() - 8;
        let mut crc = Fnv::new();
        crc.update(&buf[4..payload_end]);
        buf[payload_end..].copy_from_slice(&crc.0.to_le_bytes());
        assert!(matches!(
            read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
        ));
    }

    #[test]
    fn degrade_causes_round_trip_bytes() {
        for cause in [
            DegradeCause::DeadlineExpired,
            DegradeCause::ReplyLost,
            DegradeCause::ShardPanicked,
            DegradeCause::ShardDead,
        ] {
            assert_eq!(DegradeCause::from_u8(cause.as_u8()), Some(cause));
        }
        assert_eq!(DegradeCause::from_u8(0), None);
        assert_eq!(DegradeCause::from_u8(200), None);
    }

    #[test]
    fn error_codes_round_trip_bytes() {
        for code in [
            ErrorCode::Malformed,
            ErrorCode::UnsupportedVersion,
            ErrorCode::Oversized,
            ErrorCode::MismatchedK,
            ErrorCode::BadQuery,
            ErrorCode::MismatchedRoute,
            ErrorCode::ShuttingDown,
        ] {
            assert_eq!(ErrorCode::from_u8(code.as_u8()), Some(code));
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(99), None);
    }

    #[test]
    fn mutation_frames_round_trip() {
        let weird = f32::from_bits(0x7FC0_0055);
        let ins = Frame::Insert { id: 42, row: vec![1.0, -0.0, weird] };
        let Frame::Insert { id, row } = round_trip(&ins) else { panic!("wrong kind back") };
        assert_eq!(id, 42);
        let Frame::Insert { row: orig, .. } = ins else { unreachable!() };
        let a: Vec<u32> = orig.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = row.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "inserted rows must survive the wire bit for bit");

        let del = Frame::Delete { id: 7 };
        assert_eq!(round_trip(&del), del);
        assert_eq!(round_trip(&Frame::Compact), Frame::Compact);
        for (op, applied) in
            [(MUTATE_OP_INSERT, true), (MUTATE_OP_DELETE, false), (MUTATE_OP_COMPACT, true)]
        {
            let ok = Frame::MutateOk { op, applied, generation: 5, live: 12_345 };
            assert_eq!(round_trip(&ok), ok);
        }
        // empty-row insert is legal on the wire (the store rejects it
        // at the semantic layer with a typed BadQuery)
        let empty = Frame::Insert { id: 1, row: vec![] };
        assert_eq!(round_trip(&empty), empty);
    }

    #[test]
    fn mutation_kinds_are_rejected_on_v1_frames() {
        for frame in [
            Frame::Insert { id: 1, row: vec![1.0] },
            Frame::Delete { id: 1 },
            Frame::Compact,
            Frame::MutateOk { op: MUTATE_OP_INSERT, applied: true, generation: 0, live: 2 },
        ] {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            buf[8] = LEGACY_VERSION; // version byte (after the 4 B length prefix)
            // re-seal the crc so the version downgrade is the only fault
            let payload_end = buf.len() - 8;
            let mut crc = Fnv::new();
            crc.update(&buf[4..payload_end]);
            buf[payload_end..].copy_from_slice(&crc.0.to_le_bytes());
            match read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME) {
                Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. }) => {}
                other => panic!("v1 mutation frame must be malformed, got {other:?}"),
            }
        }
    }

    #[test]
    fn bad_mutate_ok_bytes_are_malformed() {
        for (byte_off_in_body, value) in [(0usize, 99u8), (1, 2)] {
            let ok = Frame::MutateOk { op: MUTATE_OP_INSERT, applied: true, generation: 1, live: 2 };
            let mut buf = Vec::new();
            write_frame(&mut buf, &ok).unwrap();
            buf[12 + byte_off_in_body] = value; // 4 B len + 8 B header
            let payload_end = buf.len() - 8;
            let mut crc = Fnv::new();
            crc.update(&buf[4..payload_end]);
            buf[payload_end..].copy_from_slice(&crc.0.to_le_bytes());
            assert!(matches!(
                read_frame(&mut Cursor::new(buf), DEFAULT_MAX_FRAME),
                Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
            ));
        }
    }

    // ---- satellite: zero-copy query decode ----

    #[test]
    fn query_view_is_bitwise_identical_to_owning_decode() {
        let weird = f32::from_bits(0x7FC0_1234);
        let q = QueryFrame {
            k: 7,
            route_top_m: 2,
            count: 3,
            dim: 5,
            deadline_us: 1_250,
            data: (0..15)
                .map(|i| if i == 4 { weird } else { i as f32 * 0.5 - 3.0 })
                .collect(),
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Query(q.clone())).unwrap();
        let payload = &buf[4..]; // strip the length prefix

        assert_eq!(payload_kind(payload), Some(3));
        let view = decode_query_view(payload).unwrap();
        assert_eq!(
            (view.k, view.route_top_m, view.count, view.dim, view.deadline_us),
            (q.k, q.route_top_m, q.count, q.dim, q.deadline_us)
        );

        // materialized view == owning decode, bit for bit
        let Frame::Query(owned) = decode_payload(payload).unwrap() else { panic!("kind") };
        let via_view = view.to_query_frame();
        let a: Vec<u32> = owned.data.iter().map(|x| x.to_bits()).collect();
        let b: Vec<u32> = via_view.data.iter().map(|x| x.to_bits()).collect();
        assert_eq!(a, b, "view materialization must match the owning decode bitwise");

        // row_into into a padded destination: exact row bits, zero tail
        for qi in 0..3 {
            let mut row = [0.0f32; 8];
            view.row_into(qi, &mut row);
            for c in 0..5 {
                assert_eq!(
                    row[c].to_bits(),
                    q.data[qi * 5 + c].to_bits(),
                    "query {qi} cell {c}"
                );
            }
            assert_eq!(&row[5..], &[0.0; 3], "padding lanes stay zero");
        }
    }

    #[test]
    fn query_view_rejects_exactly_what_decode_payload_rejects() {
        let q = Frame::Query(QueryFrame {
            k: 3,
            route_top_m: 0,
            count: 2,
            dim: 2,
            deadline_us: 0,
            data: vec![1.0, 2.0, 3.0, 4.0],
        });
        let mut buf = Vec::new();
        write_frame(&mut buf, &q).unwrap();
        let good = buf[4..].to_vec();
        assert!(decode_query_view(&good).is_ok());

        // corrupt crc, bad magic, nonzero flags, truncated body: both
        // decoders must refuse the same bytes with in-sync errors
        let mut variants: Vec<Vec<u8>> = Vec::new();
        let mut crc_bad = good.clone();
        let n = crc_bad.len();
        crc_bad[n - 1] ^= 0xFF;
        variants.push(crc_bad);
        let mut magic_bad = good.clone();
        magic_bad[0] = b'X';
        variants.push(magic_bad);
        let mut flag_bad = good.clone();
        flag_bad[6] = 1;
        variants.push(flag_bad);
        for cut in MIN_PAYLOAD..good.len() {
            let mut t = good[..cut - 8].to_vec();
            let mut crc = Fnv::new();
            crc.update(&t);
            t.extend_from_slice(&crc.0.to_le_bytes());
            variants.push(t);
        }
        for (i, v) in variants.iter().enumerate() {
            let a = decode_payload(v);
            let b = decode_query_view(v);
            assert!(a.is_err(), "variant {i}: owning decode must fail");
            match b {
                Err(WireError::Protocol { desync: false, .. }) => {}
                other => panic!("variant {i}: view decode must fail in-sync, got {other:?}"),
            }
            // CRC-valid truncations may differ in *message* but never
            // in acceptance
            assert_eq!(a.is_err(), b.is_err(), "variant {i}: decoders must agree");
        }

        // and a non-query kind is refused by the view decoder
        let mut ping = Vec::new();
        write_frame(&mut ping, &Frame::Ping { token: 1 }).unwrap();
        assert!(matches!(
            decode_query_view(&ping[4..]),
            Err(WireError::Protocol { code: ErrorCode::Malformed, desync: false, .. })
        ));
    }

    // ---- satellite: table-driven truncation suite ----

    /// One representative frame per kind, every supported version.
    fn frame_table() -> Vec<(&'static str, Frame)> {
        vec![
            ("ping", Frame::Ping { token: 0x0123_4567_89AB_CDEF }),
            ("pong", Frame::Pong { token: 9, n: 1_000, dim: 16, k: 10 }),
            (
                "query",
                Frame::Query(QueryFrame {
                    k: 4,
                    route_top_m: 1,
                    count: 2,
                    dim: 3,
                    deadline_us: 777,
                    data: vec![0.5, -1.5, 2.0, 3.0, -0.0, f32::INFINITY],
                }),
            ),
            (
                "results",
                Frame::Results(ResultsFrame {
                    k: 2,
                    results: vec![vec![Neighbor::new(3, 0.25)], vec![Neighbor::new(1, 0.5)]],
                    windows: vec![
                        WindowInfo { requests: 1, unique: 1, coalesced: false },
                        WindowInfo { requests: 2, unique: 1, coalesced: true },
                    ],
                }),
            ),
            (
                "error",
                Frame::Error(ErrorFrame {
                    code: ErrorCode::BadQuery,
                    detail: 16,
                    message: "dim mismatch".into(),
                }),
            ),
            ("shutdown", Frame::Shutdown),
            (
                "degraded",
                Frame::Degraded(DegradedFrame {
                    results: ResultsFrame {
                        k: 1,
                        results: vec![vec![Neighbor::new(2, 0.125)]],
                        windows: vec![WindowInfo { requests: 1, unique: 1, coalesced: false }],
                    },
                    shards_missing: vec![0, 2],
                    replicas_tried: vec![2, 0],
                    cause: DegradeCause::ShardPanicked,
                }),
            ),
            ("health", Frame::Health { token: 55 }),
            (
                "health_reply",
                Frame::HealthReply(HealthFrame {
                    token: 55,
                    threads: 3,
                    respawns: 1,
                    contained_panics: 0,
                    lost_replies: 2,
                    deadline_misses: 4,
                    shards_alive: vec![true, false, true],
                    replicas: 2,
                    hedges_sent: 1,
                    hedge_wins: 1,
                    failovers: 2,
                    replicas_alive: vec![true, false, false, true, true, false],
                }),
            ),
            ("insert", Frame::Insert { id: 11, row: vec![1.0, 2.0, 3.0] }),
            ("delete", Frame::Delete { id: 11 }),
            ("compact", Frame::Compact),
            (
                "mutate_ok",
                Frame::MutateOk {
                    op: MUTATE_OP_DELETE,
                    applied: true,
                    generation: 3,
                    live: 999,
                },
            ),
        ]
    }

    /// Mirror of the `KNNIv1` bundle-truncation suite at the frame
    /// layer: every kind, truncated at **every** byte position of its
    /// payload (which subsumes each field boundary, one-byte-in, and
    /// one-short), must come back as a typed, in-sync [`WireError`] —
    /// never a panic, never a desync once the length prefix was
    /// honored. The CRC is re-sealed at each cut so the failure under
    /// test is structural, not the checksum.
    #[test]
    fn every_kind_rejects_every_truncation_in_sync() {
        for (name, frame) in frame_table() {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            let payload = &buf[4..];
            assert_eq!(decode_payload(payload).unwrap(), frame, "{name}: full frame decodes");

            for cut in 0..payload.len() {
                let candidate: Vec<u8> = if cut < MIN_PAYLOAD + 1 {
                    // too short to even re-seal: the raw prefix
                    payload[..cut].to_vec()
                } else {
                    let mut t = payload[..cut - 8].to_vec();
                    let mut crc = Fnv::new();
                    crc.update(&t);
                    t.extend_from_slice(&crc.0.to_le_bytes());
                    t
                };
                match decode_payload(&candidate) {
                    Err(WireError::Protocol { desync: false, .. }) => {}
                    Err(other) => {
                        panic!("{name} cut {cut}: expected in-sync protocol error, got {other:?}")
                    }
                    Ok(f) => panic!("{name} cut {cut}: truncation decoded as {f:?}"),
                }
            }
        }
    }

    /// The same cuts fed through the *transport* layer: a torn frame
    /// (length prefix promising more than the stream holds) must be
    /// `Io`, and a complete-but-truncated payload stays a typed
    /// in-sync protocol error.
    #[test]
    fn every_kind_distinguishes_torn_from_truncated() {
        for (name, frame) in frame_table() {
            let mut buf = Vec::new();
            write_frame(&mut buf, &frame).unwrap();
            // tear the stream one byte short of the full frame
            let torn = &buf[..buf.len() - 1];
            assert!(
                matches!(read_frame(&mut Cursor::new(torn.to_vec()), DEFAULT_MAX_FRAME),
                    Err(WireError::Io(_))),
                "{name}: torn stream must be Io"
            );
        }
    }
}
