//! A small blocking `KNNQv1` client: connect / ping / query_batch /
//! shutdown. Used by the CLI `query --connect` path, the loopback
//! integration tests, and `bench_net_throughput`.
//!
//! Server-side rejections (typed [`Frame::Error`] replies) surface as
//! a downcastable [`ServerRejection`], so callers can distinguish "the
//! server said no" (and why) from transport failures.

use super::wire::{self, ErrorCode, Frame, QueryFrame};
use crate::api::{Neighbor, WindowInfo};
use crate::dataset::AlignedMatrix;
use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Corpus shape reported by a [`Frame::Pong`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerInfo {
    /// Rows in the served corpus.
    pub n: u64,
    /// Query dimensionality the server expects.
    pub dim: u32,
    /// The fixed `k` the server serves.
    pub k: u32,
}

/// A typed error frame received from the server, as a Rust error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerRejection {
    /// What the server objected to.
    pub code: ErrorCode,
    /// Code-specific detail (see [`ErrorCode`] docs).
    pub detail: u32,
    /// The server's human-readable context.
    pub message: String,
}

impl std::fmt::Display for ServerRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let Self { code, detail, message } = self;
        write!(f, "server rejected request: {code} (detail {detail}): {message}")
    }
}

impl std::error::Error for ServerRejection {}

/// Blocking `KNNQv1` client over one TCP connection.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    max_frame: usize,
    token: u64,
}

impl NetClient {
    /// Connect with a 30 s I/O timeout and the default max-frame cap.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> crate::Result<Self> {
        Self::connect_with(addr, Some(Duration::from_secs(30)), wire::DEFAULT_MAX_FRAME)
    }

    /// Connect with explicit read/write timeouts (`None` blocks
    /// indefinitely) and reply-frame size cap.
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        io_timeout: Option<Duration>,
        max_frame: usize,
    ) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(io_timeout)?;
        stream.set_write_timeout(io_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(Self { reader, writer, max_frame, token: 0 })
    }

    /// Send one frame and read one reply, mapping error frames to a
    /// typed [`ServerRejection`].
    fn round_trip(&mut self, frame: &Frame) -> crate::Result<Frame> {
        wire::write_frame(&mut self.writer, frame)?;
        self.writer.flush()?;
        let reply = wire::read_frame(&mut self.reader, self.max_frame)?;
        if let Frame::Error(e) = reply {
            let rejection = ServerRejection { code: e.code, detail: e.detail, message: e.message };
            return Err(anyhow::Error::new(rejection));
        }
        Ok(reply)
    }

    /// Liveness + metadata probe: returns the served corpus shape.
    pub fn ping(&mut self) -> crate::Result<ServerInfo> {
        self.token += 1;
        let token = self.token;
        match self.round_trip(&Frame::Ping { token })? {
            Frame::Pong { token: echoed, n, dim, k } => {
                anyhow::ensure!(echoed == token, "pong echoed token {echoed}, expected {token}");
                Ok(ServerInfo { n, dim, k })
            }
            other => anyhow::bail!("expected a pong, got {other:?}"),
        }
    }

    /// Send a dense query tile and block for the per-query neighbor
    /// lists plus the window diagnostics each query rode with. The
    /// tile's `f32` bit patterns cross the wire exactly, so answers
    /// are bit-identical to submitting the same rows to the server's
    /// `ServeFront` in-process.
    pub fn query_batch(
        &mut self,
        tile: &AlignedMatrix,
        k: usize,
        route_top_m: Option<usize>,
    ) -> crate::Result<(Vec<Vec<Neighbor>>, Vec<WindowInfo>)> {
        let mut data = Vec::with_capacity(tile.n() * tile.dim());
        for i in 0..tile.n() {
            data.extend_from_slice(tile.row_logical(i));
        }
        let query = QueryFrame {
            k: k as u32,
            route_top_m: route_top_m.unwrap_or(0) as u32,
            count: tile.n() as u32,
            dim: tile.dim() as u32,
            data,
        };
        match self.round_trip(&Frame::Query(query))? {
            Frame::Results(r) => {
                anyhow::ensure!(
                    r.results.len() == tile.n() && r.windows.len() == tile.n(),
                    "server answered {} results / {} windows for {} queries",
                    r.results.len(),
                    r.windows.len(),
                    tile.n()
                );
                Ok((r.results, r.windows))
            }
            other => anyhow::bail!("expected results, got {other:?}"),
        }
    }

    /// Ask the server to drain and exit; consumes the client (the
    /// connection closes after the acknowledgement).
    pub fn shutdown_server(mut self) -> crate::Result<()> {
        match self.round_trip(&Frame::Shutdown)? {
            Frame::Shutdown => Ok(()),
            other => anyhow::bail!("expected a shutdown acknowledgement, got {other:?}"),
        }
    }
}
