//! A small blocking `KNNQv1` client: connect / ping / query_batch /
//! health / shutdown. Used by the CLI `query --connect` path, the
//! loopback integration tests, and the net benches.
//!
//! Failure taxonomy, so callers (and the retry layer) can tell what
//! happened:
//!
//! * [`ServerRejection`] — a typed [`Frame::Error`] reply: the server
//!   understood the request and said no. Permanent; retrying the same
//!   request gets the same answer.
//! * [`TransportError`] — the bytes never made it: connection refused,
//!   I/O timeout, mid-stream disconnect, or another I/O failure. The
//!   first three are *transient* ([`TransportError::is_transient`]) —
//!   queries are idempotent, so [`RetryingClient`] reconnects and
//!   retries them with capped exponential backoff and deterministic
//!   seeded jitter.
//!
//! Both are downcastable from the `anyhow::Error` the methods return.

use super::wire::{self, ErrorCode, Frame, HealthFrame, QueryFrame};
use crate::api::{Degradation, Neighbor, WindowInfo};
use crate::dataset::AlignedMatrix;
use crate::util::rng::SplitMix64;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::Duration;

/// Corpus shape reported by a [`Frame::Pong`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerInfo {
    /// Rows in the served corpus.
    pub n: u64,
    /// Query dimensionality the server expects.
    pub dim: u32,
    /// The fixed `k` the server serves.
    pub k: u32,
}

/// A typed error frame received from the server, as a Rust error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ServerRejection {
    /// What the server objected to.
    pub code: ErrorCode,
    /// Code-specific detail (see [`ErrorCode`] docs).
    pub detail: u32,
    /// The server's human-readable context.
    pub message: String,
}

impl std::fmt::Display for ServerRejection {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let Self { code, detail, message } = self;
        write!(f, "server rejected request: {code} (detail {detail}): {message}")
    }
}

impl std::error::Error for ServerRejection {}

/// Why the transport failed, split by what a retry policy needs to
/// know. Everything but [`Io`](Self::Io) is transient: the failure
/// says nothing about the request itself, so an idempotent request is
/// safe to retry on a fresh connection.
#[derive(Debug)]
pub enum TransportError {
    /// The TCP connect itself failed (refused, unreachable, …).
    ConnectFailed(std::io::Error),
    /// An I/O deadline expired waiting to send or receive.
    TimedOut(std::io::Error),
    /// The peer went away mid-stream: a clean close between frames
    /// (`None`) or a reset/broken pipe/torn frame (`Some`).
    Disconnected(Option<std::io::Error>),
    /// Any other I/O failure; not assumed transient.
    Io(std::io::Error),
}

impl TransportError {
    /// True when a reconnect-and-retry has a chance of succeeding.
    pub fn is_transient(&self) -> bool {
        !matches!(self, Self::Io(_))
    }

    /// Classify an I/O error from an established stream.
    fn from_io(e: std::io::Error) -> Self {
        use std::io::ErrorKind as K;
        match e.kind() {
            // read/write timeouts surface as TimedOut or WouldBlock
            // depending on platform
            K::TimedOut | K::WouldBlock => Self::TimedOut(e),
            K::UnexpectedEof | K::ConnectionReset | K::ConnectionAborted | K::BrokenPipe => {
                Self::Disconnected(Some(e))
            }
            _ => Self::Io(e),
        }
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::ConnectFailed(e) => write!(f, "connection failed: {e}"),
            Self::TimedOut(e) => write!(f, "i/o timed out: {e}"),
            Self::Disconnected(Some(e)) => write!(f, "server disconnected mid-stream: {e}"),
            Self::Disconnected(None) => f.write_str("server closed the connection"),
            Self::Io(e) => write!(f, "transport i/o error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::ConnectFailed(e) | Self::TimedOut(e) | Self::Io(e) => Some(e),
            Self::Disconnected(e) => e.as_ref().map(|e| e as _),
        }
    }
}

/// Map a [`wire::WireError`] from an established connection into the
/// client failure taxonomy: transport failures become downcastable
/// [`TransportError`]s, protocol violations stay [`wire::WireError`].
fn wire_to_error(e: wire::WireError) -> anyhow::Error {
    match e {
        wire::WireError::Eof => anyhow::Error::new(TransportError::Disconnected(None)),
        wire::WireError::Io(io) => anyhow::Error::new(TransportError::from_io(io)),
        protocol => anyhow::Error::new(protocol),
    }
}

/// Blocking `KNNQv1` client over one TCP connection.
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    max_frame: usize,
    token: u64,
}

impl NetClient {
    /// Connect with a 30 s I/O timeout and the default max-frame cap.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> crate::Result<Self> {
        Self::connect_with(addr, Some(Duration::from_secs(30)), wire::DEFAULT_MAX_FRAME)
    }

    /// Connect with explicit read/write timeouts (`None` blocks
    /// indefinitely) and reply-frame size cap. A failed connect is a
    /// downcastable [`TransportError::ConnectFailed`].
    pub fn connect_with<A: ToSocketAddrs>(
        addr: A,
        io_timeout: Option<Duration>,
        max_frame: usize,
    ) -> crate::Result<Self> {
        let stream = TcpStream::connect(addr).map_err(TransportError::ConnectFailed)?;
        let _ = stream.set_nodelay(true);
        stream.set_read_timeout(io_timeout)?;
        stream.set_write_timeout(io_timeout)?;
        let reader = BufReader::new(stream.try_clone()?);
        let writer = BufWriter::new(stream);
        Ok(Self { reader, writer, max_frame, token: 0 })
    }

    /// Send one frame and read one reply, mapping error frames to a
    /// typed [`ServerRejection`] and transport failures to a typed
    /// [`TransportError`].
    fn round_trip(&mut self, frame: &Frame) -> crate::Result<Frame> {
        wire::write_frame(&mut self.writer, frame).map_err(TransportError::from_io)?;
        self.writer.flush().map_err(TransportError::from_io)?;
        let reply =
            wire::read_frame(&mut self.reader, self.max_frame).map_err(wire_to_error)?;
        if let Frame::Error(e) = reply {
            let rejection = ServerRejection { code: e.code, detail: e.detail, message: e.message };
            return Err(anyhow::Error::new(rejection));
        }
        Ok(reply)
    }

    /// Liveness + metadata probe: returns the served corpus shape.
    pub fn ping(&mut self) -> crate::Result<ServerInfo> {
        self.token += 1;
        let token = self.token;
        match self.round_trip(&Frame::Ping { token })? {
            Frame::Pong { token: echoed, n, dim, k } => {
                anyhow::ensure!(echoed == token, "pong echoed token {echoed}, expected {token}");
                Ok(ServerInfo { n, dim, k })
            }
            other => anyhow::bail!("expected a pong, got {other:?}"),
        }
    }

    /// Per-shard liveness and fault counters of the serving pool (all
    /// zeros with an empty shard list when the server has no pool).
    pub fn health(&mut self) -> crate::Result<HealthFrame> {
        self.token += 1;
        let token = self.token;
        match self.round_trip(&Frame::Health { token })? {
            Frame::HealthReply(h) => {
                anyhow::ensure!(
                    h.token == token,
                    "health reply echoed token {}, expected {token}",
                    h.token
                );
                Ok(h)
            }
            other => anyhow::bail!("expected a health reply, got {other:?}"),
        }
    }

    /// Send a dense query tile and block for the per-query neighbor
    /// lists plus the window diagnostics each query rode with. The
    /// tile's `f32` bit patterns cross the wire exactly, so answers
    /// are bit-identical to submitting the same rows to the server's
    /// `ServeFront` in-process.
    ///
    /// Sends no deadline and drops any degradation tag (a server
    /// serving from survivors still answers, with the honest partial
    /// merge). Callers that need the typed record use
    /// [`query_batch_deadline`](Self::query_batch_deadline).
    pub fn query_batch(
        &mut self,
        tile: &AlignedMatrix,
        k: usize,
        route_top_m: Option<usize>,
    ) -> crate::Result<(Vec<Vec<Neighbor>>, Vec<WindowInfo>)> {
        let (results, windows, _degradation) =
            self.query_batch_deadline(tile, k, route_top_m, 0)?;
        Ok((results, windows))
    }

    /// [`query_batch`](Self::query_batch) with an end-to-end latency
    /// budget in microseconds (`0` = none) and the degradation record:
    /// `None` means every shard contributed; `Some` carries which
    /// shards the server dropped and why, with the neighbors being the
    /// honest merge over the rest.
    pub fn query_batch_deadline(
        &mut self,
        tile: &AlignedMatrix,
        k: usize,
        route_top_m: Option<usize>,
        deadline_us: u64,
    ) -> crate::Result<(Vec<Vec<Neighbor>>, Vec<WindowInfo>, Option<Degradation>)> {
        let mut data = Vec::with_capacity(tile.n() * tile.dim());
        for i in 0..tile.n() {
            data.extend_from_slice(tile.row_logical(i));
        }
        let query = QueryFrame {
            k: k as u32,
            route_top_m: route_top_m.unwrap_or(0) as u32,
            count: tile.n() as u32,
            dim: tile.dim() as u32,
            deadline_us,
            data,
        };
        let (r, degradation) = match self.round_trip(&Frame::Query(query))? {
            Frame::Results(r) => (r, None),
            Frame::Degraded(d) => {
                let degradation = d.degradation();
                (d.results, Some(degradation))
            }
            other => anyhow::bail!("expected results, got {other:?}"),
        };
        anyhow::ensure!(
            r.results.len() == tile.n() && r.windows.len() == tile.n(),
            "server answered {} results / {} windows for {} queries",
            r.results.len(),
            r.windows.len(),
            tile.n()
        );
        Ok((r.results, r.windows, degradation))
    }

    /// One acknowledged mutation round trip: send, expect
    /// [`Frame::MutateOk`] echoing `op`, return `(applied, generation,
    /// live)`. A read-only server (no mutable store attached) answers
    /// with a typed [`ServerRejection`] instead.
    fn mutate(&mut self, frame: &Frame, op: u8) -> crate::Result<(bool, u64, u64)> {
        match self.round_trip(frame)? {
            Frame::MutateOk { op: echoed, applied, generation, live } => {
                anyhow::ensure!(echoed == op, "mutate-ok echoed op {echoed}, expected {op}");
                Ok((applied, generation, live))
            }
            other => anyhow::bail!("expected a mutate acknowledgement, got {other:?}"),
        }
    }

    /// Insert (or overwrite) one row in the server's mutable store.
    /// Returns `(generation, live)` after the mutation. Idempotent:
    /// re-sending the same row lands in the same state.
    pub fn insert(&mut self, id: u32, row: &[f32]) -> crate::Result<(u64, u64)> {
        let frame = Frame::Insert { id, row: row.to_vec() };
        let (_applied, generation, live) = self.mutate(&frame, wire::MUTATE_OP_INSERT)?;
        Ok((generation, live))
    }

    /// Delete one row by external id. Returns `(was_live, generation,
    /// live)`; `was_live == false` means the id was already absent (a
    /// no-op, reported honestly). Idempotent.
    pub fn delete(&mut self, id: u32) -> crate::Result<(bool, u64, u64)> {
        self.mutate(&Frame::Delete { id }, wire::MUTATE_OP_DELETE)
    }

    /// Ask the server to fold its delta and tombstones into a fresh
    /// base segment. Blocks until the fold finishes; returns the new
    /// `(generation, live)`. **Not** idempotent (every call bumps the
    /// generation), which is why [`RetryingClient`] does not wrap it.
    pub fn compact(&mut self) -> crate::Result<(u64, u64)> {
        let (_applied, generation, live) = self.mutate(&Frame::Compact, wire::MUTATE_OP_COMPACT)?;
        Ok((generation, live))
    }

    /// Ask the server to drain and exit; consumes the client (the
    /// connection closes after the acknowledgement).
    pub fn shutdown_server(mut self) -> crate::Result<()> {
        match self.round_trip(&Frame::Shutdown)? {
            Frame::Shutdown => Ok(()),
            other => anyhow::bail!("expected a shutdown acknowledgement, got {other:?}"),
        }
    }
}

/// Backoff/retry knobs for a [`RetryingClient`]. Delays grow as
/// `base_delay · 2^(attempt−1)` capped at `max_delay`, each scaled by
/// a jitter factor in `[0.5, 1.0)` drawn counter-based from `seed` —
/// the same SplitMix64 discipline as the build engine, so a replayed
/// run backs off identically.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts per operation (first try included; ≥ 1).
    pub max_attempts: u32,
    /// Backoff before the second attempt.
    pub base_delay: Duration,
    /// Backoff ceiling.
    pub max_delay: Duration,
    /// Jitter seed (deterministic: same seed, same delays).
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 3,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_secs(1),
            seed: 0,
        }
    }
}

impl RetryPolicy {
    /// The delay before attempt `attempt + 1`, given `attempt ≥ 1`
    /// failures so far: capped exponential with seeded jitter.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let doublings = attempt.saturating_sub(1).min(32);
        let exp = self
            .base_delay
            .saturating_mul(1u32 << doublings.min(31))
            .min(self.max_delay);
        let draw = SplitMix64::at(self.seed, attempt as u64).next_u64();
        let unit = (draw >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        exp.mul_f64(0.5 + 0.5 * unit)
    }
}

/// A [`NetClient`] wrapper that reconnects and retries **transient**
/// transport failures (see [`TransportError::is_transient`]) with the
/// capped, jittered backoff of a [`RetryPolicy`]. Safe because every
/// `KNNQv1` request is idempotent: a query answered twice is the same
/// answer, and a retried ping/health probe is just a fresher snapshot.
/// [`ServerRejection`]s and protocol errors are permanent and surface
/// immediately.
pub struct RetryingClient {
    addr: SocketAddr,
    policy: RetryPolicy,
    io_timeout: Option<Duration>,
    max_frame: usize,
    conn: Option<NetClient>,
    retries: u64,
}

impl RetryingClient {
    /// Resolve `addr` once and connect (retrying the connect itself
    /// under `policy`).
    pub fn connect<A: ToSocketAddrs>(addr: A, policy: RetryPolicy) -> crate::Result<Self> {
        anyhow::ensure!(policy.max_attempts >= 1, "retry policy needs at least one attempt");
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("address resolved to nothing"))?;
        let mut client = Self {
            addr,
            policy,
            io_timeout: Some(Duration::from_secs(30)),
            max_frame: wire::DEFAULT_MAX_FRAME,
            conn: None,
            retries: 0,
        };
        client.ensure_connected_with_retry()?;
        Ok(client)
    }

    /// Override the per-connection I/O timeout (`None` blocks
    /// indefinitely). Applies to the *next* (re)connect.
    pub fn io_timeout(mut self, io_timeout: Option<Duration>) -> Self {
        self.io_timeout = io_timeout;
        self
    }

    /// Transient failures retried so far (monotonic).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    fn ensure_connected(&mut self) -> crate::Result<()> {
        if self.conn.is_none() {
            self.conn = Some(NetClient::connect_with(self.addr, self.io_timeout, self.max_frame)?);
        }
        Ok(())
    }

    fn ensure_connected_with_retry(&mut self) -> crate::Result<()> {
        self.with_retry(|_client| Ok(()))
    }

    /// Run `op` over a live connection, reconnecting and retrying on
    /// transient transport failures until the policy's attempts are
    /// spent; the last error is returned.
    fn with_retry<T>(
        &mut self,
        mut op: impl FnMut(&mut NetClient) -> crate::Result<T>,
    ) -> crate::Result<T> {
        let mut attempt = 1u32;
        loop {
            let result = self.ensure_connected().and_then(|()| {
                // infallible: ensure_connected either filled `conn` or
                // errored out of the and_then chain above
                op(self.conn.as_mut().expect("connection present after ensure_connected"))
            });
            let err = match result {
                Ok(value) => return Ok(value),
                Err(err) => err,
            };
            let transient =
                err.downcast_ref::<TransportError>().is_some_and(TransportError::is_transient);
            if !transient || attempt >= self.policy.max_attempts {
                return Err(err);
            }
            // the old connection is suspect either way: rebuild
            self.conn = None;
            self.retries += 1;
            std::thread::sleep(self.policy.backoff(attempt));
            attempt += 1;
        }
    }

    /// [`NetClient::ping`] with reconnect-and-retry.
    pub fn ping(&mut self) -> crate::Result<ServerInfo> {
        self.with_retry(|c| c.ping())
    }

    /// [`NetClient::health`] with reconnect-and-retry.
    pub fn health(&mut self) -> crate::Result<HealthFrame> {
        self.with_retry(|c| c.health())
    }

    /// [`NetClient::query_batch`] with reconnect-and-retry.
    pub fn query_batch(
        &mut self,
        tile: &AlignedMatrix,
        k: usize,
        route_top_m: Option<usize>,
    ) -> crate::Result<(Vec<Vec<Neighbor>>, Vec<WindowInfo>)> {
        self.with_retry(|c| c.query_batch(tile, k, route_top_m))
    }

    /// [`NetClient::query_batch_deadline`] with reconnect-and-retry.
    pub fn query_batch_deadline(
        &mut self,
        tile: &AlignedMatrix,
        k: usize,
        route_top_m: Option<usize>,
        deadline_us: u64,
    ) -> crate::Result<(Vec<Vec<Neighbor>>, Vec<WindowInfo>, Option<Degradation>)> {
        self.with_retry(|c| c.query_batch_deadline(tile, k, route_top_m, deadline_us))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_error_classification() {
        use std::io::{Error, ErrorKind};
        assert!(matches!(
            TransportError::from_io(Error::new(ErrorKind::TimedOut, "t")),
            TransportError::TimedOut(_)
        ));
        assert!(matches!(
            TransportError::from_io(Error::new(ErrorKind::WouldBlock, "t")),
            TransportError::TimedOut(_)
        ));
        for kind in [
            ErrorKind::UnexpectedEof,
            ErrorKind::ConnectionReset,
            ErrorKind::ConnectionAborted,
            ErrorKind::BrokenPipe,
        ] {
            assert!(matches!(
                TransportError::from_io(Error::new(kind, "d")),
                TransportError::Disconnected(Some(_))
            ));
        }
        let other = TransportError::from_io(Error::new(ErrorKind::PermissionDenied, "x"));
        assert!(matches!(other, TransportError::Io(_)));
        assert!(!other.is_transient());
        assert!(TransportError::Disconnected(None).is_transient());
        assert!(TransportError::ConnectFailed(Error::new(ErrorKind::ConnectionRefused, "r"))
            .is_transient());
    }

    #[test]
    fn wire_errors_map_into_the_taxonomy() {
        let eof = wire_to_error(wire::WireError::Eof);
        assert!(matches!(
            eof.downcast_ref::<TransportError>(),
            Some(TransportError::Disconnected(None))
        ));
        let io = wire_to_error(wire::WireError::Io(std::io::Error::new(
            std::io::ErrorKind::TimedOut,
            "t",
        )));
        assert!(matches!(io.downcast_ref::<TransportError>(), Some(TransportError::TimedOut(_))));
        // protocol violations are NOT transport errors: never retried
        let proto = wire_to_error(wire::WireError::Protocol {
            code: ErrorCode::Malformed,
            detail: 0,
            message: "bad".into(),
            desync: false,
        });
        assert!(proto.downcast_ref::<TransportError>().is_none());
        assert!(proto.downcast_ref::<wire::WireError>().is_some());
    }

    #[test]
    fn backoff_is_capped_exponential_with_deterministic_jitter() {
        let policy = RetryPolicy {
            max_attempts: 10,
            base_delay: Duration::from_millis(10),
            max_delay: Duration::from_millis(200),
            seed: 42,
        };
        let delays: Vec<Duration> = (1..=8).map(|a| policy.backoff(a)).collect();
        let replay: Vec<Duration> = (1..=8).map(|a| policy.backoff(a)).collect();
        assert_eq!(delays, replay, "same seed must replay the same delays");
        for (i, d) in delays.iter().enumerate() {
            let attempt = i as u32 + 1;
            let exp = policy
                .base_delay
                .saturating_mul(1 << attempt.saturating_sub(1).min(31))
                .min(policy.max_delay);
            assert!(*d >= exp.mul_f64(0.5), "attempt {attempt}: {d:?} below jitter floor");
            // <= not <: mul_f64 rounds to the nanosecond, so a draw at
            // the top of the jitter band can land exactly on exp
            assert!(*d <= exp, "attempt {attempt}: {d:?} above un-jittered {exp:?}");
        }
        // a different seed jitters differently (overwhelmingly likely
        // across 8 draws)
        let other = RetryPolicy { seed: 43, ..policy };
        assert_ne!(delays, (1..=8).map(|a| other.backoff(a)).collect::<Vec<_>>());
        // deep attempts saturate at the cap's jitter band, no overflow
        assert!(policy.backoff(100) <= policy.max_delay);
    }

    #[test]
    fn connect_refused_is_typed_and_retry_gives_up() {
        // bind-then-drop gives a port with (almost certainly) no
        // listener; connect must fail as ConnectFailed
        let addr = {
            let sock = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            sock.local_addr().unwrap()
        };
        let err = NetClient::connect_with(addr, Some(Duration::from_millis(200)), 1024)
            .err()
            .expect("connect to a dead port must fail");
        assert!(matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::ConnectFailed(_))
        ));
        let policy = RetryPolicy {
            max_attempts: 2,
            base_delay: Duration::from_millis(1),
            max_delay: Duration::from_millis(2),
            seed: 7,
        };
        let err = RetryingClient::connect(addr, policy).err().expect("retries must give up");
        assert!(matches!(
            err.downcast_ref::<TransportError>(),
            Some(TransportError::ConnectFailed(_))
        ));
    }
}
