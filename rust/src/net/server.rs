//! The `KNNQv1` server runtime: a `std::net::TcpListener` accept loop
//! feeding a **bounded** pool of connection-handler workers, each
//! decoding frames straight into the owned-tile path of the existing
//! [`ServeFront`] micro-batching windows — so cross-connection
//! batching and duplicate-query coalescing apply across the wire
//! exactly as they do in-process.
//!
//! Robustness contract:
//!
//! * **Never panics on wire input** — every decode failure is a typed
//!   [`Frame::Error`] reply (in-sync errors keep the connection open;
//!   a desynced stream is closed).
//! * **One slow or hostile client cannot wedge the pool** — per-
//!   connection read/write timeouts drop silent connections back to
//!   the worker, and the max-frame-size guard rejects giant length
//!   prefixes before allocating.
//! * **Partial answers beat no answers** — a query frame carrying a
//!   `deadline_us` budget is submitted with a deadline; if the pool
//!   underneath drops shards (deadline missed, worker dead) the reply
//!   is a typed [`Frame::Degraded`] carrying the honest partial merge,
//!   and [`Frame::Health`] probes report per-shard liveness plus the
//!   pool's fault counters at any time.
//! * **Graceful shutdown drains in-flight windows** — a SIGINT (via
//!   [`install_sigint_handler`]), a wire [`Frame::Shutdown`], or
//!   [`ServerHandle::request_shutdown`] stops the accept loop, lets
//!   every worker finish its current frame (open connections close at
//!   the next frame boundary; queued queries answer
//!   [`ErrorCode::ShuttingDown`]), then joins the workers and shuts
//!   the front down, which serves everything already queued.

use super::wire::{
    self, DegradedFrame, ErrorCode, ErrorFrame, Frame, HealthFrame, QueryFrame, QueryView,
    ResultsFrame, WireError,
};
use crate::api::{Degradation, FrontStats, KMismatch, ServeFront, ShardState};
use crate::store::SharedMutableIndex;
use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Knobs for a [`NetServer`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Connection-handler worker threads (≥ 1). Also the capacity of
    /// the bounded accepted-connection queue: with every worker busy
    /// and the queue full, the accept loop itself applies backpressure.
    pub workers: usize,
    /// A connection that sends no complete frame within this window is
    /// closed (the anti-wedge guarantee: silence returns the worker to
    /// the pool).
    pub read_timeout: Duration,
    /// A peer that will not drain its replies within this window is
    /// closed.
    pub write_timeout: Duration,
    /// Maximum accepted payload length; larger prefixes are rejected
    /// as [`ErrorCode::Oversized`] without being read.
    pub max_frame: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            read_timeout: Duration::from_secs(30),
            write_timeout: Duration::from_secs(10),
            max_frame: wire::DEFAULT_MAX_FRAME,
        }
    }
}

/// Lifetime totals for one server run (monotonic counters).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct NetStats {
    /// Connections accepted.
    pub connections: u64,
    /// Well-formed frames handled.
    pub frames: u64,
    /// Query rows received over the wire.
    pub queries: u64,
    /// Protocol violations answered with typed error frames.
    pub protocol_errors: u64,
}

#[derive(Default)]
struct NetCounters {
    connections: AtomicU64,
    frames: AtomicU64,
    queries: AtomicU64,
    protocol_errors: AtomicU64,
}

impl NetCounters {
    fn snapshot(&self) -> NetStats {
        NetStats {
            connections: self.connections.load(Ordering::Relaxed),
            frames: self.frames.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            protocol_errors: self.protocol_errors.load(Ordering::Relaxed),
        }
    }
}

/// Process-wide SIGINT latch checked by every accept loop.
static SIGINT_HIT: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_sigint(_signum: i32) {
    SIGINT_HIT.store(true, Ordering::SeqCst);
}

/// Install a SIGINT handler that asks every running [`NetServer`] to
/// drain and exit gracefully (the CLI `serve` path calls this). Uses
/// the raw libc `signal(2)` symbol so the crate stays free of new
/// dependencies; a no-op on non-unix targets.
#[cfg(unix)]
pub fn install_sigint_handler() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    unsafe {
        signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
    }
}

/// No-op outside unix; `Ctrl-C` falls back to process termination.
#[cfg(not(unix))]
pub fn install_sigint_handler() {}

/// A bound-but-not-yet-running `KNNQv1` server over a [`ServeFront`].
pub struct NetServer {
    listener: TcpListener,
    front: ServeFront,
    cfg: ServerConfig,
    /// Mutation surface: when present, `Insert`/`Delete`/`Compact`
    /// frames are applied here; without it they get a typed read-only
    /// rejection. The front should be spawned over a *clone* of the
    /// same handle so searches observe the mutations.
    store: Option<SharedMutableIndex>,
}

impl NetServer {
    /// Bind `addr` (use `127.0.0.1:0` for an ephemeral test port) in
    /// front of `front`. The front's `k`/`dim`/routing become the
    /// served contract: wire queries must match them.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        front: ServeFront,
        cfg: ServerConfig,
    ) -> crate::Result<Self> {
        anyhow::ensure!(cfg.workers >= 1, "server needs at least one worker");
        anyhow::ensure!(cfg.max_frame >= wire::MIN_PAYLOAD, "max_frame below minimum payload");
        let listener = TcpListener::bind(addr)?;
        // non-blocking accept so the loop can poll the shutdown latch
        listener.set_nonblocking(true)?;
        Ok(Self { listener, front, cfg, store: None })
    }

    /// Attach a mutable store: `Insert`/`Delete`/`Compact` frames are
    /// applied to it and `Ping` reports its live row count. For
    /// mutations to be visible to queries, `front` must have been
    /// spawned over a clone of this same handle, and its answer cache
    /// must be disabled (a cached answer would outlive the rows it
    /// names; [`crate::api::FrontConfig::answer_cache`] `= 0`).
    pub fn with_store(mut self, store: SharedMutableIndex) -> Self {
        self.store = Some(store);
        self
    }

    /// The bound address (resolves the actual port after binding `:0`).
    pub fn local_addr(&self) -> crate::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Run the accept loop on the calling thread until a shutdown
    /// frame or SIGINT arrives, then drain and return the totals.
    pub fn run(self) -> crate::Result<(NetStats, FrontStats)> {
        self.run_inner(Arc::new(AtomicBool::new(false)))
    }

    /// Run on a background thread; the returned handle exposes the
    /// bound address and a graceful-stop switch (tests and benches).
    pub fn spawn(self) -> crate::Result<ServerHandle> {
        let addr = self.listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let join = std::thread::Builder::new()
            .name("knng-net-accept".into())
            .spawn(move || self.run_inner(flag))?;
        Ok(ServerHandle { addr, shutdown, join })
    }

    fn run_inner(self, shutdown: Arc<AtomicBool>) -> crate::Result<(NetStats, FrontStats)> {
        let NetServer { listener, front, cfg, store } = self;
        let front = Arc::new(front);
        let counters = Arc::new(NetCounters::default());
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.workers);
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        let mut workers = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let rx = Arc::clone(&conn_rx);
            let front = Arc::clone(&front);
            let flag = Arc::clone(&shutdown);
            let counters = Arc::clone(&counters);
            let cfg = cfg.clone();
            let store = store.clone();
            let worker = std::thread::Builder::new()
                .name(format!("knng-net-worker-{i}"))
                .spawn(move || worker_loop(rx, front, cfg, flag, counters, store))?;
            workers.push(worker);
        }
        loop {
            if shutdown.load(Ordering::SeqCst) || SIGINT_HIT.load(Ordering::SeqCst) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    counters.connections.fetch_add(1, Ordering::Relaxed);
                    if conn_tx.send(stream).is_err() {
                        break; // every worker died; nothing can serve
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(1));
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        // drain: stop accepting, let workers finish queued connections
        // and their current frames, then shut the front down (which
        // serves every window already submitted).
        shutdown.store(true, Ordering::SeqCst);
        drop(conn_tx);
        for worker in workers {
            let _ = worker.join();
        }
        let net = counters.snapshot();
        let front = match Arc::try_unwrap(front) {
            Ok(front) => front,
            Err(_) => anyhow::bail!("a worker leaked the serve front"),
        };
        let front_stats = front.shutdown();
        Ok((net, front_stats))
    }
}

/// Handle to a server spawned on a background thread.
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    join: JoinHandle<crate::Result<(NetStats, FrontStats)>>,
}

impl ServerHandle {
    /// The address clients should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Flip the graceful-stop switch; the accept loop notices within
    /// its poll interval and begins draining.
    pub fn request_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
    }

    /// Wait for the server to finish and return its totals.
    pub fn join(self) -> crate::Result<(NetStats, FrontStats)> {
        match self.join.join() {
            Ok(res) => res,
            Err(_) => Err(anyhow::anyhow!("server thread panicked")),
        }
    }

    /// [`request_shutdown`](Self::request_shutdown) + [`join`](Self::join).
    pub fn stop(self) -> crate::Result<(NetStats, FrontStats)> {
        self.request_shutdown();
        self.join()
    }
}

fn worker_loop(
    rx: Arc<Mutex<mpsc::Receiver<TcpStream>>>,
    front: Arc<ServeFront>,
    cfg: ServerConfig,
    shutdown: Arc<AtomicBool>,
    counters: Arc<NetCounters>,
    store: Option<SharedMutableIndex>,
) {
    loop {
        let stream = {
            // poison recovery, not a panic: the queue itself is just a
            // Receiver, always consistent, and a sibling worker that
            // panicked while holding the lock must not cascade into
            // killing every other worker
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        let Ok(stream) = stream else {
            return; // accept loop gone and queue drained: worker done
        };
        // one connection's failure never takes the worker down
        let _ = handle_connection(stream, &front, &cfg, &shutdown, &counters, store.as_ref());
    }
}

fn handle_connection(
    stream: TcpStream,
    front: &ServeFront,
    cfg: &ServerConfig,
    shutdown: &AtomicBool,
    counters: &NetCounters,
    store: Option<&SharedMutableIndex>,
) -> crate::Result<()> {
    let _ = stream.set_nodelay(true); // latency over batching at the TCP layer
    stream.set_read_timeout(Some(cfg.read_timeout))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let payload = match wire::read_payload(&mut reader, cfg.max_frame) {
            Ok(payload) => payload,
            Err(WireError::Eof) => return Ok(()), // clean hang-up
            Err(WireError::Io(_)) => return Ok(()), // torn frame, reset, or read timeout
            Err(WireError::Protocol { code, detail, message, desync }) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let reply = Frame::Error(ErrorFrame { code, detail, message });
                let _ = wire::write_frame(&mut writer, &reply);
                let _ = writer.flush();
                if desync {
                    return Ok(()); // length prefix untrustworthy: close
                }
                continue; // exactly `len` bytes consumed: still framed
            }
        };

        // Fast path: a query frame is decoded as a borrowed view and
        // its rows are read straight out of `payload` into the
        // submission buffers — one decode pass, no intermediate tile.
        // The view decoder accepts and rejects exactly the byte strings
        // `decode_payload` would, so the protocol is unchanged.
        if wire::payload_kind(&payload) == Some(wire::KIND_QUERY) {
            let reply = match wire::decode_query_view(&payload) {
                Ok(view) => {
                    counters.frames.fetch_add(1, Ordering::Relaxed);
                    if shutdown.load(Ordering::SeqCst) {
                        error_reply(ErrorCode::ShuttingDown, 0, "server is draining".into())
                    } else {
                        counters.queries.fetch_add(view.count as u64, Ordering::Relaxed);
                        serve_query_view(front, &view)
                    }
                }
                Err(WireError::Protocol { code, detail, message, .. }) => {
                    // the whole payload was already consumed: in-sync
                    counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    Frame::Error(ErrorFrame { code, detail, message })
                }
                Err(_) => return Ok(()), // unreachable: the decoder is pure
            };
            wire::write_frame(&mut writer, &reply)?;
            writer.flush()?;
            if shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            continue;
        }

        let frame = match wire::decode_payload(&payload) {
            Ok(frame) => frame,
            Err(WireError::Protocol { code, detail, message, .. }) => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let reply = Frame::Error(ErrorFrame { code, detail, message });
                let _ = wire::write_frame(&mut writer, &reply);
                let _ = writer.flush();
                continue; // decode failures are in-sync by construction
            }
            Err(_) => return Ok(()), // unreachable: the decoder is pure
        };
        counters.frames.fetch_add(1, Ordering::Relaxed);
        let reply = match frame {
            Frame::Ping { token } => Frame::Pong {
                token,
                // a mutable store's corpus moves; report its live count
                n: match store {
                    Some(s) => s.live_len() as u64,
                    None => front.corpus_len() as u64,
                },
                dim: front.dim() as u32,
                k: front.serving_k() as u32,
            },
            Frame::Shutdown => {
                // acknowledge, then latch the graceful drain
                shutdown.store(true, Ordering::SeqCst);
                let _ = wire::write_frame(&mut writer, &Frame::Shutdown);
                let _ = writer.flush();
                return Ok(());
            }
            Frame::Query(q) => {
                // cold path kept for completeness; kind 3 is normally
                // routed through the view decoder above
                if shutdown.load(Ordering::SeqCst) {
                    error_reply(ErrorCode::ShuttingDown, 0, "server is draining".into())
                } else {
                    counters.queries.fetch_add(q.count as u64, Ordering::Relaxed);
                    serve_query(front, q)
                }
            }
            Frame::Insert { id, row } => serve_mutation(store, front, || {
                let s = store.expect("serve_mutation checked the store");
                s.insert(id, &row)?;
                Ok((wire::MUTATE_OP_INSERT, true))
            }),
            Frame::Delete { id } => serve_mutation(store, front, || {
                let s = store.expect("serve_mutation checked the store");
                let was_live = s.delete(id)?;
                Ok((wire::MUTATE_OP_DELETE, was_live))
            }),
            Frame::Compact => serve_mutation(store, front, || {
                let s = store.expect("serve_mutation checked the store");
                s.compact()?;
                Ok((wire::MUTATE_OP_COMPACT, true))
            }),
            Frame::Health { token } => health_reply(front, token),
            Frame::Pong { .. } | Frame::Results(_) | Frame::Error(_) | Frame::Degraded(_)
            | Frame::HealthReply(_) | Frame::MutateOk { .. } => {
                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                let msg = "unexpected server-to-client frame kind".to_string();
                error_reply(ErrorCode::Malformed, 0, msg)
            }
        };
        wire::write_frame(&mut writer, &reply)?;
        writer.flush()?;
        if shutdown.load(Ordering::SeqCst) {
            return Ok(()); // drain reached this connection's frame boundary
        }
    }
}

/// Run one mutation against the attached store (typed read-only
/// rejection without one) and answer with the post-mutation
/// generation + live count, or a typed [`ErrorCode::BadQuery`] when
/// the store refused it (dim mismatch, reserved id, compaction on a
/// near-empty corpus, …).
fn serve_mutation(
    store: Option<&SharedMutableIndex>,
    front: &ServeFront,
    op: impl FnOnce() -> crate::Result<(u8, bool)>,
) -> Frame {
    let Some(s) = store else {
        let msg = "this server is read-only (no mutable store attached)".to_string();
        return error_reply(ErrorCode::BadQuery, front.dim() as u32, msg);
    };
    match op() {
        Ok((op, applied)) => Frame::MutateOk {
            op,
            applied,
            generation: s.generation(),
            live: s.live_len() as u64,
        },
        Err(e) => error_reply(ErrorCode::BadQuery, front.dim() as u32, format!("{e:#}")),
    }
}

/// Validate the fixed fields of a query (owning or view form) against
/// the front's served contract; `Some` is the typed error reply.
fn validate_query(front: &ServeFront, dim: u32, route_top_m: u32) -> Option<Frame> {
    if dim as usize != front.dim() {
        let msg = format!("query dim {dim} does not match served dim {}", front.dim());
        return Some(error_reply(ErrorCode::BadQuery, front.dim() as u32, msg));
    }
    let configured = front.route_top_m().unwrap_or(0);
    if route_top_m as usize != configured {
        let msg =
            format!("requested route_top_m {route_top_m} but this server serves {configured}");
        return Some(error_reply(ErrorCode::MismatchedRoute, configured as u32, msg));
    }
    None
}

/// Validate one owning query frame and run it through the
/// micro-batching windows (the cold path; the server normally decodes
/// queries as views and goes through [`serve_query_view`]).
fn serve_query(front: &ServeFront, q: QueryFrame) -> Frame {
    if let Some(reply) = validate_query(front, q.dim, q.route_top_m) {
        return reply;
    }
    let dim = q.dim as usize;
    serve_rows(front, q.k, q.deadline_us, q.data.chunks_exact(dim).map(<[f32]>::to_vec))
}

/// The zero-copy serving path: each row is decoded from the borrowed
/// frame buffer straight into its own submission buffer — one decode
/// pass, no intermediate tile vector. Answers are bit-identical to
/// [`serve_query`] because [`QueryView::row_into`] reads the same LE
/// `f32` bit patterns [`wire::decode_payload`] would materialize.
fn serve_query_view(front: &ServeFront, q: &QueryView<'_>) -> Frame {
    if let Some(reply) = validate_query(front, q.dim, q.route_top_m) {
        return reply;
    }
    let dim = q.dim as usize;
    serve_rows(
        front,
        q.k,
        q.deadline_us,
        (0..q.count as usize).map(|qi| {
            let mut row = vec![0.0f32; dim];
            q.row_into(qi, &mut row);
            row
        }),
    )
}

/// Submit pre-validated rows through the micro-batching windows. Tile
/// rows are submitted individually, so rows from *different*
/// connections coalesce into shared windows — the wire inherits the
/// in-process batching semantics (and the in-process answers, bit for
/// bit).
fn serve_rows(
    front: &ServeFront,
    wire_k: u32,
    deadline_us: u64,
    rows: impl Iterator<Item = Vec<f32>>,
) -> Frame {
    let k = wire_k as usize;
    let budget = Duration::from_micros(deadline_us);
    let mut tickets = Vec::new();
    for row in rows {
        let submitted = if deadline_us > 0 {
            front.submit_with_k_deadline(row, k, budget)
        } else {
            front.submit_with_k(row, k)
        };
        match submitted {
            Ok(ticket) => tickets.push(ticket),
            Err(e) => {
                // tickets already submitted are simply dropped: the
                // front ignores dead reply receivers by design
                if let Some(m) = e.downcast_ref::<KMismatch>() {
                    return error_reply(ErrorCode::MismatchedK, m.serving as u32, m.to_string());
                }
                return error_reply(ErrorCode::BadQuery, 0, format!("submit failed: {e}"));
            }
        }
    }
    let mut results = Vec::with_capacity(tickets.len());
    let mut windows = Vec::with_capacity(tickets.len());
    // a tile's rows may ride in different windows; the frame-level
    // degradation is their union (all missing shards, worst cause)
    let mut degradation: Option<Degradation> = None;
    for ticket in tickets {
        match ticket.wait() {
            Ok(served) => {
                results.push(served.neighbors);
                windows.push(served.window);
                if let Some(d) = served.degradation {
                    degradation = Some(match degradation.take() {
                        None => d,
                        Some(mut acc) => {
                            acc.cause = acc.cause.max(d.cause);
                            // merge the parallel (shard, replicas-tried)
                            // lists: union of shards, max tried per shard
                            let mut pairs: Vec<(u32, u32)> = acc
                                .shards_missing
                                .iter()
                                .zip(&acc.replicas_tried)
                                .chain(d.shards_missing.iter().zip(&d.replicas_tried))
                                .map(|(&s, &t)| (s, t))
                                .collect();
                            pairs.sort_unstable();
                            pairs.dedup_by(|next, kept| {
                                if next.0 == kept.0 {
                                    kept.1 = kept.1.max(next.1);
                                    true
                                } else {
                                    false
                                }
                            });
                            acc.shards_missing = pairs.iter().map(|&(s, _)| s).collect();
                            acc.replicas_tried = pairs.iter().map(|&(_, t)| t).collect();
                            acc
                        }
                    });
                }
            }
            Err(e) => {
                return error_reply(ErrorCode::ShuttingDown, 0, format!("front went away: {e}"));
            }
        }
    }
    let frame = ResultsFrame { k: wire_k, results, windows };
    match degradation {
        None => Frame::Results(frame),
        Some(d) => Frame::Degraded(DegradedFrame {
            results: frame,
            shards_missing: d.shards_missing,
            replicas_tried: d.replicas_tried,
            cause: d.cause,
        }),
    }
}

/// Answer a health probe from the front's live pool view; a front over
/// a plain (unsupervised) searcher reports zero threads and no shards.
fn health_reply(front: &ServeFront, token: u64) -> Frame {
    match front.health() {
        Some(stats) => Frame::HealthReply(HealthFrame {
            token,
            threads: stats.threads as u32,
            respawns: stats.respawns,
            contained_panics: stats.contained_panics,
            lost_replies: stats.lost_replies,
            deadline_misses: stats.deadline_misses,
            shards_alive: stats.shards.iter().map(|s| *s == ShardState::Healthy).collect(),
            replicas: stats.replicas as u32,
            hedges_sent: stats.hedges_sent,
            hedge_wins: stats.hedge_wins,
            failovers: stats.failovers,
            replicas_alive: stats.replicas_alive_flat(),
        }),
        None => Frame::HealthReply(HealthFrame {
            token,
            threads: 0,
            respawns: 0,
            contained_panics: 0,
            lost_replies: 0,
            deadline_misses: 0,
            shards_alive: Vec::new(),
            replicas: 1,
            hedges_sent: 0,
            hedge_wins: 0,
            failovers: 0,
            replicas_alive: Vec::new(),
        }),
    }
}

fn error_reply(code: ErrorCode, detail: u32, message: String) -> Frame {
    Frame::Error(ErrorFrame { code, detail, message })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn server_config_defaults_are_sane() {
        let cfg = ServerConfig::default();
        assert!(cfg.workers >= 1);
        assert!(cfg.read_timeout > Duration::ZERO);
        assert!(cfg.write_timeout > Duration::ZERO);
        assert!(cfg.max_frame >= wire::MIN_PAYLOAD);
    }

    #[test]
    fn error_reply_wraps_code_and_detail() {
        let frame = error_reply(ErrorCode::MismatchedK, 10, "nope".into());
        let Frame::Error(e) = frame else { panic!("expected an error frame") };
        assert_eq!(e.code, ErrorCode::MismatchedK);
        assert_eq!(e.detail, 10);
        assert_eq!(e.message, "nope");
    }
}
