//! # `net` — network serving for the sharded, micro-batched stack
//!
//! Turns [`ServeFront`](crate::api::ServeFront) into an actual server:
//!
//! * [`wire`] — `KNNQv1`, a compact length-prefixed binary protocol,
//!   versioned and FNV-checksummed in the same style as the `KNNIv1`
//!   index bundle; decoding never panics on wire input.
//! * [`server`] — a `TcpListener` accept loop plus a bounded worker
//!   pool of connection handlers that submit decoded query rows into
//!   the existing micro-batching windows, so cross-connection batching
//!   and duplicate coalescing apply across the wire; graceful shutdown
//!   (SIGINT / shutdown frame) drains in-flight windows.
//! * [`client`] — a small blocking client (connect / ping / health /
//!   query_batch / insert / delete / compact / shutdown) for
//!   `query --connect`, the `store` CLI, the loopback tests, and
//!   `bench_net_throughput`, plus [`RetryingClient`], which reconnects
//!   and retries transient transport failures with capped,
//!   deterministically jittered backoff.
//!
//! Protocol version 2 adds a mutation surface for servers with a
//! mutable store attached ([`NetServer::with_store`]): `Insert` /
//! `Delete` / `Compact` frames acknowledged by `MutateOk`, and the
//! server decodes query frames **zero-copy** — rows are read from the
//! borrowed frame buffer straight into the submission buffers
//! ([`wire::QueryView`]), with answers bit-identical to the owning
//! decode.
//!
//! The serving contract: a query tile served over loopback is
//! **bit-identical** to the same tile submitted to the `ServeFront`
//! in-process — `f32` values cross the wire as exact bit patterns and
//! the server adds no computation of its own. Under faults the server
//! degrades rather than fails: answers merged from surviving shards
//! arrive as `Degraded` frames carrying a typed record of what was
//! missing, and `Health` probes expose per-shard liveness.

pub mod client;
pub mod server;
pub mod wire;

pub use client::{NetClient, RetryPolicy, RetryingClient, ServerInfo, ServerRejection, TransportError};
pub use server::{install_sigint_handler, NetServer, NetStats, ServerConfig, ServerHandle};
pub use wire::{
    DegradedFrame, ErrorCode, ErrorFrame, Frame, HealthFrame, QueryFrame, QueryView,
    ResultsFrame, WireError,
};
