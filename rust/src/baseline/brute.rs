//! Exact K-NN by exhaustive pairwise evaluation — the ground truth for
//! every recall number in EXPERIMENTS.md (paper §2 validates ≥99% recall
//! against this).
//!
//! O(n²·d): fine up to a few tens of thousands of points; for larger n
//! use [`brute_force_knn_sampled`], which computes exact neighbors for a
//! deterministic subset of query nodes only (recall estimated on the
//! sample, as is standard for ANN benchmarks).

use crate::dataset::AlignedMatrix;
use crate::graph::heap::{heap_push, sorted_neighbors, EMPTY_ID};
use crate::util::rng::Pcg64;

/// Exact neighbor lists for a set of query nodes.
#[derive(Debug, Clone)]
pub struct GroundTruth {
    pub k: usize,
    /// (query node id, its exact k-NN sorted ascending by distance)
    pub queries: Vec<(u32, Vec<(u32, f32)>)>,
}

impl GroundTruth {
    /// Look up a query's truth list (None if not sampled).
    pub fn get(&self, u: u32) -> Option<&[(u32, f32)]> {
        self.queries
            .binary_search_by_key(&u, |q| q.0)
            .ok()
            .map(|i| self.queries[i].1.as_slice())
    }
}

/// Exact K-NN for every node.
pub fn brute_force_knn(data: &AlignedMatrix, k: usize) -> GroundTruth {
    let all: Vec<u32> = (0..data.n() as u32).collect();
    exact_for_queries(data, k, &all)
}

/// Exact K-NN for `m` deterministically sampled query nodes.
pub fn brute_force_knn_sampled(data: &AlignedMatrix, k: usize, m: usize, seed: u64) -> GroundTruth {
    let n = data.n();
    if m >= n {
        return brute_force_knn(data, k);
    }
    let mut rng = Pcg64::new_stream(seed, 0x6007);
    let mut qs = Vec::new();
    rng.sample_indices(n, m, &mut qs);
    qs.sort_unstable();
    exact_for_queries(data, k, &qs)
}

fn exact_for_queries(data: &AlignedMatrix, k: usize, queries: &[u32]) -> GroundTruth {
    let n = data.n();
    let k = k.min(n - 1);
    // resolve the dispatched pair kernel once for the O(n·|queries|) scan
    let pair = crate::distance::dispatch::active().pair;
    let mut out = Vec::with_capacity(queries.len());
    let mut ids = vec![EMPTY_ID; k];
    let mut dists = vec![f32::INFINITY; k];
    let mut flags = vec![false; k];
    for &q in queries {
        ids.fill(EMPTY_ID);
        dists.fill(f32::INFINITY);
        let a = data.row(q as usize);
        for v in 0..n as u32 {
            if v == q {
                continue;
            }
            let d = pair(a, data.row(v as usize));
            heap_push(&mut ids, &mut dists, &mut flags, v, d, false);
        }
        out.push((q, sorted_neighbors(&ids, &dists)));
    }
    GroundTruth { k, queries: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::SynthGaussian;

    #[test]
    fn exact_on_a_line() {
        // points at x = 0,1,2,3,4 → neighbors are the adjacent ones
        let data = AlignedMatrix::from_rows(5, 1, &[0.0, 1.0, 2.0, 3.0, 4.0]);
        let gt = brute_force_knn(&data, 2);
        let n0 = gt.get(0).unwrap();
        assert_eq!(n0[0], (1, 1.0));
        assert_eq!(n0[1], (2, 4.0));
        let n2 = gt.get(2).unwrap();
        let ids: Vec<u32> = n2.iter().map(|p| p.0).collect();
        assert!(ids.contains(&1) && ids.contains(&3));
    }

    #[test]
    fn sampled_subset_consistent_with_full() {
        let data = SynthGaussian::single(200, 8, 5).generate();
        let full = brute_force_knn(&data, 5);
        let sampled = brute_force_knn_sampled(&data, 5, 20, 42);
        assert_eq!(sampled.queries.len(), 20);
        for (q, list) in &sampled.queries {
            assert_eq!(full.get(*q).unwrap(), list.as_slice());
        }
        // sampling with m >= n falls back to full
        let all = brute_force_knn_sampled(&data, 5, 500, 42);
        assert_eq!(all.queries.len(), 200);
    }

    #[test]
    fn lists_sorted_and_exclude_self() {
        let data = SynthGaussian::single(100, 8, 9).generate();
        let gt = brute_force_knn(&data, 10);
        for (q, list) in &gt.queries {
            assert_eq!(list.len(), 10);
            assert!(list.windows(2).all(|w| w[0].1 <= w[1].1), "sorted");
            assert!(list.iter().all(|&(v, _)| v != *q), "no self");
        }
    }
}
