//! Comparators: exact brute-force K-NN (ground truth for recall) and a
//! faithful Rust port of PyNNDescent's algorithmic profile (the paper's
//! external baseline in Table 2).

pub mod brute;
pub mod pynnd;

pub use brute::{brute_force_knn, brute_force_knn_sampled, GroundTruth};
pub use pynnd::PyNndBaseline;
