//! PyNNDescent-profile baseline (the paper's external comparator,
//! Table 2).
//!
//! PyNNDescent is Python + numba; reproducing interpreter/JIT overhead
//! in Rust would be theater. What *is* reproducible — and what isolates
//! the paper's claimed wins — is PyNNDescent's algorithmic profile:
//!
//! * fused selection with bounded random-weight **heaps** (not
//!   turbosampling),
//! * **pair-at-a-time** distance evaluation (generic-metric design ⇒ no
//!   blocking),
//! * **no** dimension padding / alignment guarantees (generic ndarray),
//! * **no** memory reordering.
//!
//! Relative factors against this baseline are therefore conservative
//! lower bounds on the paper's reported gaps (which additionally include
//! Python overhead); the *ordering* of Table 2 must still hold.

use crate::config::schema::{ComputeKind, SelectionKind};
use crate::dataset::AlignedMatrix;
use crate::nndescent::driver::BuildResult;
use crate::nndescent::{NnDescent, Params};

/// Baseline runner with PyNNDescent's defaults.
#[derive(Debug, Clone)]
pub struct PyNndBaseline {
    pub k: usize,
    pub rho: f64,
    pub delta: f64,
    pub max_iters: usize,
    pub seed: u64,
}

impl Default for PyNndBaseline {
    fn default() -> Self {
        // PyNNDescent defaults: n_neighbors=30 in the library, but the
        // paper benchmarks both sides at k=20, ρ=0.5, δ=0.001.
        Self { k: 20, rho: 0.5, delta: 0.001, max_iters: 40, seed: 1 }
    }
}

impl PyNndBaseline {
    pub fn with_k(mut self, k: usize) -> Self {
        self.k = k;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Build the graph with the baseline profile.
    pub fn build(&self, data: &AlignedMatrix) -> BuildResult {
        let params = Params {
            k: self.k,
            rho: self.rho,
            delta: self.delta,
            max_iters: self.max_iters,
            seed: self.seed,
            selection: SelectionKind::Heap,
            compute: ComputeKind::Scalar,
            reorder: false,
            reorder_iter: 1,
            max_candidates: 60, // pynndescent's internal cap
            threads: 1,         // the baseline is explicitly single-core
        };
        NnDescent::new(params)
            .build(data)
            .expect("baseline profile uses only native backends")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baseline::brute::brute_force_knn;
    use crate::dataset::synth::SynthGaussian;
    use crate::metrics::recall::recall_against_truth;

    #[test]
    fn baseline_reaches_high_recall() {
        let data = SynthGaussian::single(600, 16, 31).generate();
        let truth = brute_force_knn(&data, 10);
        let r = PyNndBaseline::default().with_k(10).with_seed(31).build(&data);
        let rec = recall_against_truth(&r, &truth);
        assert!(rec > 0.95, "baseline recall {rec}");
    }

    #[test]
    fn baseline_profile_is_heap_scalar() {
        // the profile must match the doc contract (guards refactors)
        let b = PyNndBaseline::default();
        let params = Params {
            k: b.k,
            rho: b.rho,
            delta: b.delta,
            max_iters: b.max_iters,
            seed: b.seed,
            selection: SelectionKind::Heap,
            compute: ComputeKind::Scalar,
            reorder: false,
            reorder_iter: 1,
            max_candidates: 60,
            threads: 1,
        };
        assert_eq!(params.selection, SelectionKind::Heap);
        assert_eq!(params.compute, ComputeKind::Scalar);
        assert!(!params.reorder);
    }
}
