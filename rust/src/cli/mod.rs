//! Hand-rolled command-line parsing (no `clap` offline).
//!
//! Declarative-enough: an [`ArgSpec`] lists the flags a subcommand
//! accepts; [`parse_args`] validates and produces an [`ArgMatches`] with
//! typed getters. Supports `--flag value`, `--flag=value`, boolean
//! `--flag`, repeated flags, and positional arguments.

use std::collections::BTreeMap;

/// Kind of value a flag takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArgKind {
    /// Boolean presence flag.
    Flag,
    /// Flag taking exactly one value.
    Value,
    /// Flag that may repeat, collecting values.
    Multi,
}

/// One accepted flag.
#[derive(Debug, Clone)]
pub struct ArgDef {
    pub name: &'static str,
    pub kind: ArgKind,
    pub help: &'static str,
}

/// A subcommand's accepted flags and positionals.
#[derive(Debug, Clone, Default)]
pub struct ArgSpec {
    pub args: Vec<ArgDef>,
    /// Max number of positional arguments (0 = none allowed).
    pub max_positional: usize,
}

impl ArgSpec {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgDef { name, kind: ArgKind::Flag, help });
        self
    }
    pub fn value(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgDef { name, kind: ArgKind::Value, help });
        self
    }
    pub fn multi(mut self, name: &'static str, help: &'static str) -> Self {
        self.args.push(ArgDef { name, kind: ArgKind::Multi, help });
        self
    }
    pub fn positionals(mut self, max: usize) -> Self {
        self.max_positional = max;
        self
    }

    fn find(&self, name: &str) -> Option<&ArgDef> {
        self.args.iter().find(|a| a.name == name)
    }

    /// Render a `--help`-style usage block.
    pub fn usage(&self, cmd: &str) -> String {
        let mut out = format!("usage: knng {cmd} [options]");
        if self.max_positional > 0 {
            out.push_str(" [args...]");
        }
        out.push('\n');
        for a in &self.args {
            let form = match a.kind {
                ArgKind::Flag => format!("--{}", a.name),
                ArgKind::Value => format!("--{} <v>", a.name),
                ArgKind::Multi => format!("--{} <v>...", a.name),
            };
            out.push_str(&format!("  {form:<24} {}\n", a.help));
        }
        out
    }
}

/// Parsed arguments with typed getters.
#[derive(Debug, Clone, Default)]
pub struct ArgMatches {
    flags: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

/// Parse error with a human-readable message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

impl ArgMatches {
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.first()).map(|s| s.as_str())
    }
    pub fn get_all(&self, name: &str) -> &[String] {
        self.flags.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }
    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => {
                parse_usize(s).ok_or_else(|| CliError(format!("--{name}: bad integer `{s}`")))
            }
        }
    }
    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => {
                parse_u64(s).ok_or_else(|| CliError(format!("--{name}: bad integer `{s}`")))
            }
        }
    }
    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse::<f64>().map_err(|_| CliError(format!("--{name}: bad float `{s}`"))),
        }
    }
    /// Comma- or repeat-separated f32 list (`--vec 0.5,-1.25`); what
    /// `knng store insert --vec` feeds the mutable store with.
    pub fn f32_list(&self, name: &str) -> Result<Vec<f32>, CliError> {
        let mut out = Vec::new();
        for raw in self.get_all(name) {
            for part in raw.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                out.push(
                    part.parse::<f32>()
                        .map_err(|_| CliError(format!("--{name}: bad float `{part}`")))?,
                );
            }
        }
        Ok(out)
    }

    /// Comma- or repeat-separated usize list (`--dims 8,64 --dims 256`).
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        let mut out = Vec::new();
        for raw in self.get_all(name) {
            for part in raw.split(',') {
                let part = part.trim();
                if part.is_empty() {
                    continue;
                }
                out.push(
                    parse_usize(part)
                        .ok_or_else(|| CliError(format!("--{name}: bad integer `{part}`")))?,
                );
            }
        }
        Ok(out)
    }
}

/// Accept `16384`, `16_384`, and `16k`/`1M` suffixes (×1024 / ×1024²).
/// The one integer grammar every numeric getter shares, so `--n 16k`
/// and `--seed 16k` parse identically.
fn parse_u64(s: &str) -> Option<u64> {
    let s = s.replace('_', "");
    if let Some(num) = s.strip_suffix(['k', 'K']) {
        return num.parse::<u64>().ok()?.checked_mul(1024);
    }
    if let Some(num) = s.strip_suffix(['m', 'M']) {
        return num.parse::<u64>().ok()?.checked_mul(1024 * 1024);
    }
    s.parse::<u64>().ok()
}

/// [`parse_u64`] narrowed to usize.
fn parse_usize(s: &str) -> Option<usize> {
    parse_u64(s).and_then(|v| usize::try_from(v).ok())
}

/// The `--kernel` flag definition shared by every subcommand that runs
/// distance kernels (attach with `.value(KERNEL_FLAG, KERNEL_HELP)`).
pub const KERNEL_FLAG: &str = "kernel";
/// Help string for [`KERNEL_FLAG`].
pub const KERNEL_HELP: &str = "force distance-kernel width: scalar|w8|w16 (default: PALLAS_KERNEL env, else CPU detect)";

/// Apply a parsed `--kernel` override to the process-global distance
/// dispatcher ([`crate::distance::dispatch::force`]). Call once at
/// subcommand startup, before any kernel work; absent flag = no change
/// (env/CPU selection stays in effect).
pub fn apply_kernel_override(m: &ArgMatches) -> Result<(), CliError> {
    if let Some(s) = m.get(KERNEL_FLAG) {
        let w = crate::distance::dispatch::KernelWidth::parse(s).ok_or_else(|| {
            CliError(format!("--{KERNEL_FLAG}: unknown width `{s}` (scalar|w8|w16)"))
        })?;
        crate::distance::dispatch::force(Some(w));
    }
    Ok(())
}

/// Parse `argv` (excluding the program/subcommand names) against a spec.
pub fn parse_args(spec: &ArgSpec, argv: &[String]) -> Result<ArgMatches, CliError> {
    let mut m = ArgMatches::default();
    let mut i = 0;
    while i < argv.len() {
        let tok = &argv[i];
        if let Some(body) = tok.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let def = spec
                .find(name)
                .ok_or_else(|| CliError(format!("unknown flag --{name}")))?;
            match def.kind {
                ArgKind::Flag => {
                    if inline.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    m.flags.entry(name.to_string()).or_default();
                }
                ArgKind::Value | ArgKind::Multi => {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} requires a value")))?
                        }
                    };
                    let entry = m.flags.entry(name.to_string()).or_default();
                    if def.kind == ArgKind::Value && !entry.is_empty() {
                        return Err(CliError(format!("--{name} given more than once")));
                    }
                    entry.push(value);
                }
            }
        } else {
            if m.positional.len() >= spec.max_positional {
                return Err(CliError(format!("unexpected positional argument `{tok}`")));
            }
            m.positional.push(tok.clone());
        }
        i += 1;
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new()
            .flag("verbose", "chatty output")
            .value("n", "number of points")
            .value("rho", "sample rate")
            .multi("dims", "dimension list")
            .positionals(1)
    }

    fn argv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_all_forms() {
        let m = parse_args(
            &spec(),
            &argv(&["--verbose", "--n=16k", "--rho", "0.5", "--dims", "8,64", "--dims", "256", "pos"]),
        )
        .unwrap();
        assert!(m.has("verbose"));
        assert_eq!(m.usize_or("n", 0).unwrap(), 16 * 1024);
        assert_eq!(m.f64_or("rho", 0.0).unwrap(), 0.5);
        assert_eq!(m.usize_list("dims").unwrap(), vec![8, 64, 256]);
        assert_eq!(m.positional, vec!["pos"]);
    }

    #[test]
    fn defaults_when_absent() {
        let m = parse_args(&spec(), &argv(&[])).unwrap();
        assert!(!m.has("verbose"));
        assert_eq!(m.usize_or("n", 42).unwrap(), 42);
        assert_eq!(m.str_or("n", "x"), "x");
        assert!(m.usize_list("dims").unwrap().is_empty());
    }

    #[test]
    fn errors() {
        assert!(parse_args(&spec(), &argv(&["--bogus"])).is_err());
        assert!(parse_args(&spec(), &argv(&["--n"])).is_err());
        assert!(parse_args(&spec(), &argv(&["--verbose=1"])).is_err());
        assert!(parse_args(&spec(), &argv(&["--n", "1", "--n", "2"])).is_err());
        assert!(parse_args(&spec(), &argv(&["a", "b"])).is_err(), "too many positionals");
        let m = parse_args(&spec(), &argv(&["--n", "abc"])).unwrap();
        assert!(m.usize_or("n", 0).is_err());
    }

    #[test]
    fn f32_list_parses_and_rejects() {
        let spec = ArgSpec::new().multi("vec", "row");
        let m = parse_args(&spec, &argv(&["--vec", "0.5,-1.25", "--vec", "3"])).unwrap();
        assert_eq!(m.f32_list("vec").unwrap(), vec![0.5, -1.25, 3.0]);
        let m = parse_args(&spec, &argv(&["--vec", "0.5,abc"])).unwrap();
        assert!(m.f32_list("vec").is_err());
        let m = parse_args(&spec, &argv(&[])).unwrap();
        assert!(m.f32_list("vec").unwrap().is_empty());
    }

    #[test]
    fn suffix_parsing() {
        assert_eq!(parse_usize("131072"), Some(131072));
        assert_eq!(parse_usize("128k"), Some(131072));
        assert_eq!(parse_usize("1M"), Some(1 << 20));
        assert_eq!(parse_usize("16_384"), Some(16384));
        assert_eq!(parse_usize("x"), None);
        // the u64 path shares the same grammar
        assert_eq!(parse_u64("128k"), Some(131072));
        assert_eq!(parse_u64("1M"), Some(1 << 20));
        assert_eq!(parse_u64("16_384"), Some(16384));
        assert_eq!(parse_u64("9x"), None);
        // and overflow is a parse failure, not a wrap
        assert_eq!(parse_u64("18446744073709551615k"), None);
    }

    #[test]
    fn numeric_edge_cases_error_instead_of_panicking() {
        // suffix overflow at the u64 boundary: 2^54·1024 and 2^44·1024²
        // are exactly 2^64 — checked_mul must turn both into None
        assert_eq!(parse_u64("18014398509481984k"), None);
        assert_eq!(parse_u64("17592186044416M"), None);
        // one below the boundary still parses
        assert_eq!(parse_u64("18014398509481983k"), Some(u64::MAX - 1023)); // 2^64 − 1024
        assert_eq!(parse_u64("17592186044415M"), Some(((1 << 44) - 1) * (1 << 20)));
        // u64::MAX without a suffix is fine; one more is not
        assert_eq!(parse_u64("18446744073709551615"), Some(u64::MAX));
        assert_eq!(parse_u64("18446744073709551616"), None);
        // degenerate strings: bare separators, empty, suffix-only, junk
        for s in ["", "_", "__", "k", "K", "M", "m", "_k", "_M", "-1", " 1", "1 ", "1.5k"] {
            assert_eq!(parse_u64(s), None, "`{s}` must not parse");
            assert_eq!(parse_usize(s), None, "`{s}` must not parse as usize");
        }

        // and through the getters: a CliError, never a panic
        let spec = ArgSpec::new().value("n", "count");
        for raw in ["18014398509481984k", "_", ""] {
            let m = parse_args(&spec, &argv(&["--n", raw])).unwrap();
            assert!(m.usize_or("n", 0).is_err(), "`{raw}` via usize_or");
            assert!(m.u64_or("n", 0).is_err(), "`{raw}` via u64_or");
            assert!(m.usize_list("n").is_err() || raw.is_empty(), "`{raw}` via usize_list");
        }
    }

    #[test]
    fn u64_and_usize_getters_accept_identical_inputs() {
        let spec = ArgSpec::new().value("n", "count").value("seed", "seed");
        for raw in ["16k", "1M", "16_384", "42"] {
            let m = parse_args(&spec, &argv(&["--n", raw, "--seed", raw])).unwrap();
            let n = m.usize_or("n", 0).unwrap();
            let seed = m.u64_or("seed", 0).unwrap();
            assert_eq!(n as u64, seed, "`{raw}` must parse identically on both paths");
        }
        // both reject the same garbage
        let m = parse_args(&spec, &argv(&["--n", "16q", "--seed", "16q"])).unwrap();
        assert!(m.usize_or("n", 0).is_err());
        assert!(m.u64_or("seed", 0).is_err());
    }

    #[test]
    fn kernel_override_flag_validates() {
        // only the error/no-op paths run here: actually forcing a width
        // is process-global and would race concurrently-running kernel
        // tests (the CLI calls it from single-threaded main)
        let spec = ArgSpec::new().value(KERNEL_FLAG, KERNEL_HELP);
        let bad = parse_args(&spec, &argv(&["--kernel", "avx9000"])).unwrap();
        let err = apply_kernel_override(&bad).unwrap_err();
        assert!(err.0.contains("unknown width"), "{err}");
        let none = parse_args(&spec, &argv(&[])).unwrap();
        assert!(apply_kernel_override(&none).is_ok());
    }

    #[test]
    fn usage_renders() {
        let u = spec().usage("build");
        assert!(u.contains("--n <v>"));
        assert!(u.contains("--dims <v>..."));
        assert!(u.contains("chatty output"));
    }
}
