//! Graph persistence: a small versioned binary format so built graphs
//! can be saved once and served many times (`knng build --save`, the
//! `graph_search` example, downstream pipelines).
//!
//! Layout (little-endian):
//!
//! ```text
//! magic   8 B   "KNNGv1\0\0"
//! n       8 B   u64
//! k       8 B   u64
//! ids     n·k·4 B  u32 (EMPTY_ID for open slots), heap order
//! dists   n·k·4 B  f32
//! crc     8 B   FNV-1a over everything above
//! ```
//!
//! Flags and counters are *not* serialized — a saved graph is a finished
//! artifact, not a resumable build; on load all flags are false and the
//! counters are rebuilt from the edges.

use super::heap::EMPTY_ID;
use super::knng::KnnGraph;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"KNNGv1\0\0";

/// FNV-1a streaming hasher (integrity check without external deps).
/// Shared with the KNNIv1 index-bundle format (`search::bundle`).
pub(crate) struct Fnv(pub(crate) u64);
impl Fnv {
    pub(crate) fn new() -> Self {
        Self(0xcbf29ce484222325)
    }
    pub(crate) fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
}

/// Serialize a graph.
pub fn save_graph(path: &Path, graph: &KnnGraph) -> Result<()> {
    let f = std::fs::File::create(path).with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    let mut crc = Fnv::new();
    let emit = |w: &mut BufWriter<std::fs::File>, crc: &mut Fnv, bytes: &[u8]| -> Result<()> {
        crc.update(bytes);
        w.write_all(bytes)?;
        Ok(())
    };
    emit(&mut w, &mut crc, MAGIC)?;
    emit(&mut w, &mut crc, &(graph.n() as u64).to_le_bytes())?;
    emit(&mut w, &mut crc, &(graph.k() as u64).to_le_bytes())?;
    for u in 0..graph.n() {
        for &v in graph.ids(u) {
            emit(&mut w, &mut crc, &v.to_le_bytes())?;
        }
    }
    for u in 0..graph.n() {
        for &d in graph.dists(u) {
            emit(&mut w, &mut crc, &d.to_le_bytes())?;
        }
    }
    w.write_all(&crc.0.to_le_bytes())?;
    w.flush()?;
    Ok(())
}

/// Rebuild a [`KnnGraph`] from deserialized id/dist strips: push in
/// strip order (re-heapifies and rebuilds every counter; distances are
/// preserved exactly), validating each edge. Shared by the `KNNGv1`
/// graph format and the `KNNIv1` index-bundle format.
pub(crate) fn rebuild_graph(n: usize, k: usize, ids: &[u32], dists: &[f32]) -> Result<KnnGraph> {
    debug_assert_eq!(ids.len(), n * k);
    debug_assert_eq!(dists.len(), n * k);
    let mut graph = KnnGraph::new(n, k);
    for u in 0..n {
        for i in 0..k {
            let v = ids[u * k + i];
            if v == EMPTY_ID {
                continue;
            }
            if v as usize >= n || v as usize == u {
                bail!("corrupt edge {u} → {v}");
            }
            graph.push(u, v, dists[u * k + i], false);
        }
    }
    Ok(graph)
}

/// Deserialize a graph (validates magic, sizes, and checksum).
pub fn load_graph(path: &Path) -> Result<KnnGraph> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(f);
    let mut crc = Fnv::new();

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).context("reading magic")?;
    if &magic != MAGIC {
        bail!("not a KNNGv1 file (magic {:02x?})", magic);
    }
    crc.update(&magic);

    let mut buf8 = [0u8; 8];
    r.read_exact(&mut buf8)?;
    crc.update(&buf8);
    let n = u64::from_le_bytes(buf8) as usize;
    r.read_exact(&mut buf8)?;
    crc.update(&buf8);
    let k = u64::from_le_bytes(buf8) as usize;
    if n < 2 || k < 1 || n.checked_mul(k).is_none() || n * k > (1 << 34) {
        bail!("implausible graph header: n={n}, k={k}");
    }

    let mut ids = vec![0u32; n * k];
    let mut dists = vec![0f32; n * k];
    let mut buf4 = [0u8; 4];
    for slot in ids.iter_mut() {
        r.read_exact(&mut buf4)?;
        crc.update(&buf4);
        *slot = u32::from_le_bytes(buf4);
    }
    for slot in dists.iter_mut() {
        r.read_exact(&mut buf4)?;
        crc.update(&buf4);
        *slot = f32::from_le_bytes(buf4);
    }
    r.read_exact(&mut buf8).context("reading checksum")?;
    if u64::from_le_bytes(buf8) != crc.0 {
        bail!("checksum mismatch — file corrupt");
    }

    rebuild_graph(n, k, &ids, &dists)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synth::SynthGaussian;
    use crate::nndescent::{NnDescent, Params};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("knng_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_neighbor_sets() {
        let data = SynthGaussian::single(300, 16, 5).generate();
        let built = NnDescent::new(Params::default().with_k(8).with_seed(5)).build(&data).unwrap();
        let path = tmp("g.knng");
        save_graph(&path, &built.graph).unwrap();
        let loaded = load_graph(&path).unwrap();
        loaded.validate().unwrap();
        assert_eq!(loaded.n(), 300);
        assert_eq!(loaded.k(), 8);
        for u in 0..300 {
            assert_eq!(built.graph.sorted(u), loaded.sorted(u), "node {u}");
        }
    }

    #[test]
    fn detects_corruption() {
        let data = SynthGaussian::single(100, 8, 1).generate();
        let built = NnDescent::new(Params::default().with_k(5).with_seed(1)).build(&data).unwrap();
        let path = tmp("c.knng");
        save_graph(&path, &built.graph).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        let err = load_graph(&path).unwrap_err().to_string();
        assert!(
            err.contains("checksum") || err.contains("corrupt"),
            "unexpected error: {err}"
        );
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let path = tmp("bad.knng");
        std::fs::write(&path, b"NOTKNNG!aaaa").unwrap();
        assert!(load_graph(&path).is_err());
        std::fs::write(&path, &MAGIC[..]).unwrap();
        assert!(load_graph(&path).is_err(), "truncated header");
    }

    #[test]
    fn partially_filled_graph_roundtrips() {
        let mut g = crate::graph::KnnGraph::new(10, 4);
        g.push(0, 1, 1.5, true);
        g.push(3, 7, 0.25, false);
        let path = tmp("partial.knng");
        save_graph(&path, &g).unwrap();
        let loaded = load_graph(&path).unwrap();
        loaded.validate().unwrap();
        assert_eq!(loaded.sorted(0), vec![(1, 1.5)]);
        assert_eq!(loaded.sorted(3), vec![(7, 0.25)]);
        assert!(loaded.sorted(5).is_empty());
    }
}
