//! K-NN graph state: bounded neighbor heaps (SoA) and the graph
//! container with the bookkeeping NN-Descent needs (incremental-search
//! `new` flags, reverse-degree counters for turbosampling, update
//! counting for the convergence test).

pub mod heap;
pub mod io;
pub mod knng;

pub use heap::{heap_push, siftdown, EMPTY_ID};
pub use io::{load_graph, save_graph};
pub use knng::{GraphUpdate, KnnGraph};
