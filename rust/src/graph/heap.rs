//! Fixed-capacity neighbor max-heap operating on borrowed SoA slices.
//!
//! Each node's k-nearest list is a binary max-heap keyed by distance:
//! the root (index 0) is the *worst* current neighbor, so an improvement
//! test is a single comparison against `dists[0]`, and a replacement is
//! a root pop + sift-down — O(log k). IDs, distances, and the
//! NN-Descent `new` flags live in separate arrays (`KnnGraph` owns them
//! as n×k strips); this module only manipulates one node's strip.

/// Sentinel id meaning "slot not yet filled" (valid ids are < n ≤ u32::MAX).
pub const EMPTY_ID: u32 = u32::MAX;

/// Push candidate `(id, dist, flag)` into the heap strip if it improves
/// on the current worst and is not already present. Returns `true` if
/// the heap changed (this is the "update" counted for convergence).
///
/// Duplicate detection is a linear scan — k is small (20) and the scan
/// is branch-predictable; PyNNDescent makes the same trade-off.
#[inline]
pub fn heap_push(ids: &mut [u32], dists: &mut [f32], flags: &mut [bool], id: u32, dist: f32, flag: bool) -> bool {
    debug_assert_eq!(ids.len(), dists.len());
    debug_assert_eq!(ids.len(), flags.len());
    if dist >= dists[0] {
        return false;
    }
    // reject duplicates
    if ids.contains(&id) {
        return false;
    }
    // replace root, restore heap property
    ids[0] = id;
    dists[0] = dist;
    flags[0] = flag;
    siftdown(ids, dists, flags, 0);
    true
}

/// Restore the max-heap property downward from `start`.
#[inline]
pub fn siftdown(ids: &mut [u32], dists: &mut [f32], flags: &mut [bool], start: usize) {
    let k = ids.len();
    let mut i = start;
    loop {
        let l = 2 * i + 1;
        let r = l + 1;
        let mut largest = i;
        if l < k && dists[l] > dists[largest] {
            largest = l;
        }
        if r < k && dists[r] > dists[largest] {
            largest = r;
        }
        if largest == i {
            return;
        }
        ids.swap(i, largest);
        dists.swap(i, largest);
        flags.swap(i, largest);
        i = largest;
    }
}

/// Check the max-heap invariant (test helper).
pub fn is_heap(dists: &[f32]) -> bool {
    (1..dists.len()).all(|i| dists[(i - 1) / 2] >= dists[i])
}

/// Extract (id, dist) pairs sorted ascending by distance (heap-sort into
/// a fresh vec; used when emitting final results and by the reorder
/// heuristic's `sorted(adj)` step).
pub fn sorted_neighbors(ids: &[u32], dists: &[f32]) -> Vec<(u32, f32)> {
    let mut pairs: Vec<(u32, f32)> = ids
        .iter()
        .zip(dists)
        .filter(|(&id, _)| id != EMPTY_ID)
        .map(|(&id, &d)| (id, d))
        .collect();
    pairs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    pairs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    fn fresh(k: usize) -> (Vec<u32>, Vec<f32>, Vec<bool>) {
        (vec![EMPTY_ID; k], vec![f32::INFINITY; k], vec![false; k])
    }

    #[test]
    fn fills_then_replaces_worst() {
        let (mut ids, mut dists, mut flags) = fresh(3);
        assert!(heap_push(&mut ids, &mut dists, &mut flags, 10, 5.0, true));
        assert!(heap_push(&mut ids, &mut dists, &mut flags, 11, 3.0, true));
        assert!(heap_push(&mut ids, &mut dists, &mut flags, 12, 4.0, true));
        // full; 6.0 is worse than the worst (5.0) → rejected
        assert!(!heap_push(&mut ids, &mut dists, &mut flags, 13, 6.0, true));
        // 1.0 replaces the current worst
        assert!(heap_push(&mut ids, &mut dists, &mut flags, 14, 1.0, true));
        assert!(!ids.contains(&10), "worst (id 10, d=5.0) evicted");
        assert!(is_heap(&dists));
    }

    #[test]
    fn rejects_duplicates() {
        let (mut ids, mut dists, mut flags) = fresh(4);
        assert!(heap_push(&mut ids, &mut dists, &mut flags, 7, 2.0, true));
        assert!(!heap_push(&mut ids, &mut dists, &mut flags, 7, 1.0, true), "same id rejected");
        assert_eq!(ids.iter().filter(|&&i| i == 7).count(), 1);
    }

    #[test]
    fn prop_heap_holds_topk_of_stream() {
        check(Config::cases(100), "heap = top-k of pushed stream", |g| {
            let k = g.usize_in(1..16);
            let m = g.usize_in(1..100);
            let (mut ids, mut dists, mut flags) = fresh(k);
            let mut pushed: Vec<(u32, f32)> = Vec::new();
            for id in 0..m as u32 {
                let d = g.f32_unit() * 100.0;
                heap_push(&mut ids, &mut dists, &mut flags, id, d, false);
                pushed.push((id, d));
            }
            pushed.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
            let expect: std::collections::BTreeSet<u32> =
                pushed.iter().take(k).map(|p| p.0).collect();
            let got: std::collections::BTreeSet<u32> =
                ids.iter().copied().filter(|&i| i != EMPTY_ID).collect();
            is_heap(&dists) && got == expect
        });
    }

    #[test]
    fn prop_heap_invariant_after_every_push() {
        check(Config::cases(100), "heap invariant maintained", |g| {
            let k = g.usize_in(2..20);
            let (mut ids, mut dists, mut flags) = fresh(k);
            for id in 0..50u32 {
                heap_push(&mut ids, &mut dists, &mut flags, id, g.f32_unit(), g.bool(0.5));
                if !is_heap(&dists) {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn sorted_neighbors_ascending_and_skips_empty() {
        let (mut ids, mut dists, mut flags) = fresh(5);
        for (id, d) in [(3, 9.0), (1, 2.0), (2, 5.0)] {
            heap_push(&mut ids, &mut dists, &mut flags, id, d, false);
        }
        let s = sorted_neighbors(&ids, &dists);
        assert_eq!(s, vec![(1, 2.0), (2, 5.0), (3, 9.0)]);
    }

    #[test]
    fn flags_travel_with_entries() {
        let (mut ids, mut dists, mut flags) = fresh(3);
        heap_push(&mut ids, &mut dists, &mut flags, 1, 3.0, true);
        heap_push(&mut ids, &mut dists, &mut flags, 2, 2.0, false);
        heap_push(&mut ids, &mut dists, &mut flags, 3, 1.0, true);
        for i in 0..3 {
            match ids[i] {
                1 => assert!(flags[i]),
                2 => assert!(!flags[i]),
                3 => assert!(flags[i]),
                _ => unreachable!(),
            }
        }
    }
}
