//! The K-NN graph container: n × k SoA strips plus NN-Descent
//! bookkeeping (neighborhood-size counters, update counting).

use super::heap::{siftdown, sorted_neighbors, EMPTY_ID};

/// One buffered candidate improvement from a parallel compute phase:
/// "`nb` at distance `dist` may improve `target`'s list". Workers emit
/// these instead of touching the heaps; [`KnnGraph::apply_updates`]
/// replays a whole buffer in one deterministic merge.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GraphUpdate {
    /// Node whose neighbor list the update targets.
    pub target: u32,
    /// Candidate neighbor id.
    pub nb: u32,
    /// Squared-L2 distance between `target` and `nb`.
    pub dist: f32,
}

impl GraphUpdate {
    /// The one total order on update records — (target, distance,
    /// neighbor id) — shared by [`KnnGraph::apply_updates`] and the
    /// compute phase's buffer compaction. They **must** sort
    /// identically: compaction's losslessness proof ("a record outside
    /// its per-target 2k prefix is outside the merged apply prefix")
    /// only holds when both sites use this exact ordering.
    ///
    /// `f32::total_cmp` keeps the comparator total (squared-L2
    /// distances are never `-0.0` or NaN on this path, so it agrees
    /// with the numeric order while staying panic-free).
    #[inline]
    pub fn order(a: &GraphUpdate, b: &GraphUpdate) -> std::cmp::Ordering {
        a.target
            .cmp(&b.target)
            .then_with(|| a.dist.total_cmp(&b.dist))
            .then_with(|| a.nb.cmp(&b.nb))
    }
}

/// Approximate K-NN graph under construction.
///
/// Storage is struct-of-arrays: separate `ids` / `dists` / `flags`
/// strips of length `n·k`. The strips for node `u` occupy
/// `[u·k, (u+1)·k)` and form a max-heap by distance (worst at the
/// front), so the membership/improvement test on the hot path touches
/// exactly one cache line of distances first.
///
/// The graph maintains, incrementally on every mutation, the sizes of
/// each node's *new* and *old* neighborhoods — the paper's turbosampling
/// bookkeeping ("upon every update of the KNN-graph we keep track of how
/// large the neighborhood of every node is"; updates touch these nodes'
/// strips anyway, so the counters cost no extra cache misses):
///
/// * `fwd_new[u]` — forward neighbors of `u` carrying the `new` flag,
/// * `rev_new[v]` — nodes whose lists contain `v` flagged new,
/// * `rev_old[v]` — nodes whose lists contain `v` unflagged.
#[derive(Debug, Clone)]
pub struct KnnGraph {
    n: usize,
    k: usize,
    ids: Vec<u32>,
    dists: Vec<f32>,
    flags: Vec<bool>,
    filled: Vec<u16>,
    fwd_new: Vec<u16>,
    rev_new: Vec<u32>,
    rev_old: Vec<u32>,
}

impl KnnGraph {
    /// Empty graph: all slots open (EMPTY_ID / +∞ / not-new).
    pub fn new(n: usize, k: usize) -> Self {
        assert!(k >= 1 && n >= 2, "need n ≥ 2, k ≥ 1");
        assert!(n <= u32::MAX as usize - 1, "ids are u32");
        assert!(k <= u16::MAX as usize);
        Self {
            n,
            k,
            ids: vec![EMPTY_ID; n * k],
            dists: vec![f32::INFINITY; n * k],
            flags: vec![false; n * k],
            filled: vec![0; n],
            fwd_new: vec![0; n],
            rev_new: vec![0; n],
            rev_old: vec![0; n],
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Neighbor ids of node `u` (heap order, may contain EMPTY_ID early).
    #[inline]
    pub fn ids(&self, u: usize) -> &[u32] {
        &self.ids[u * self.k..(u + 1) * self.k]
    }

    /// Neighbor distances of node `u` (heap order).
    #[inline]
    pub fn dists(&self, u: usize) -> &[f32] {
        &self.dists[u * self.k..(u + 1) * self.k]
    }

    /// Incremental-search flags of node `u` (aligned with `ids`).
    #[inline]
    pub fn flags(&self, u: usize) -> &[bool] {
        &self.flags[u * self.k..(u + 1) * self.k]
    }

    /// The whole neighbor-id strip, `n·k` slots in heap order — node
    /// `u`'s slice is `[u·k, (u+1)·k)`. This is the flat layout the
    /// search core's [`IndexView`](crate::search) borrows and the
    /// `KNNIv2` segment writer persists verbatim.
    #[inline]
    pub fn flat_ids(&self) -> &[u32] {
        &self.ids
    }

    /// The whole neighbor-distance strip, aligned with
    /// [`flat_ids`](Self::flat_ids).
    #[inline]
    pub fn flat_dists(&self) -> &[f32] {
        &self.dists
    }

    /// Clear the `new` flag of slot `i` in `u`'s strip, maintaining the
    /// neighborhood-size counters. No-op if already old or empty.
    #[inline]
    pub fn clear_flag(&mut self, u: usize, i: usize) {
        let base = u * self.k;
        if self.flags[base + i] {
            self.flags[base + i] = false;
            let v = self.ids[base + i];
            debug_assert!(v != EMPTY_ID);
            self.fwd_new[u] -= 1;
            self.rev_new[v as usize] -= 1;
            self.rev_old[v as usize] += 1;
        }
    }

    /// Current worst (largest) distance in `u`'s list — the improvement
    /// threshold.
    #[inline]
    pub fn worst(&self, u: usize) -> f32 {
        self.dists[u * self.k]
    }

    /// Size of `u`'s *new* neighborhood: flagged forward + flagged
    /// reverse edges (the denominator of turbosampling's coin flip).
    #[inline]
    pub fn new_size(&self, u: usize) -> u32 {
        self.fwd_new[u] as u32 + self.rev_new[u]
    }

    /// Size of `u`'s *old* neighborhood.
    #[inline]
    pub fn old_size(&self, u: usize) -> u32 {
        (self.filled[u] - self.fwd_new[u]) as u32 + self.rev_old[u]
    }

    /// |N(u)| = forward + reverse neighborhood size.
    #[inline]
    pub fn neighborhood_size(&self, u: usize) -> u32 {
        self.filled[u] as u32 + self.rev_new[u] + self.rev_old[u]
    }

    /// Reverse degree (both flags) — diagnostics.
    #[inline]
    pub fn reverse_degree(&self, u: usize) -> u32 {
        self.rev_new[u] + self.rev_old[u]
    }

    /// Try to insert `(v, dist)` into `u`'s list with the `new` flag set.
    /// Returns true if the graph changed. Maintains all counters for the
    /// inserted and the evicted neighbor.
    #[inline]
    pub fn push(&mut self, u: usize, v: u32, dist: f32, flag: bool) -> bool {
        debug_assert!(u < self.n && (v as usize) < self.n && v as usize != u);
        let base = u * self.k;
        let strip = base..base + self.k;
        if dist >= self.dists[base] {
            return false;
        }
        if self.ids[strip.clone()].contains(&v) {
            return false;
        }
        let evicted = self.ids[base];
        let evicted_flag = self.flags[base];
        self.ids[base] = v;
        self.dists[base] = dist;
        self.flags[base] = flag;
        siftdown(
            &mut self.ids[strip.clone()],
            &mut self.dists[strip.clone()],
            &mut self.flags[strip],
            0,
        );
        if evicted != EMPTY_ID {
            if evicted_flag {
                self.rev_new[evicted as usize] -= 1;
                self.fwd_new[u] -= 1;
            } else {
                self.rev_old[evicted as usize] -= 1;
            }
        } else {
            self.filled[u] += 1;
        }
        if flag {
            self.rev_new[v as usize] += 1;
            self.fwd_new[u] += 1;
        } else {
            self.rev_old[v as usize] += 1;
        }
        true
    }

    /// Apply a buffer of candidate updates in one deterministic phased
    /// merge: records are sorted by (target, distance, neighbor id) and
    /// replayed through [`push`](Self::push), so the outcome is a pure
    /// function of the update *set* — independent of which worker
    /// produced a record first or how per-thread buffers were
    /// concatenated. `push`'s usual rules reject records that no longer
    /// improve a list or duplicate an existing neighbor, and applying
    /// best-first per target means a record is only counted when it
    /// survives every better record for the same node. All updates carry
    /// the `new` flag, matching the sequential compute step. Drains the
    /// buffer; returns the number of successful updates (the convergence
    /// signal `c` in Dong et al.).
    pub fn apply_updates(&mut self, updates: &mut Vec<GraphUpdate>) -> u64 {
        updates.sort_unstable_by(GraphUpdate::order);
        let mut applied = 0u64;
        for rec in updates.iter() {
            if self.push(rec.target as usize, rec.nb, rec.dist, true) {
                applied += 1;
            }
        }
        updates.clear();
        applied
    }

    /// Neighbors of `u` sorted ascending by distance.
    pub fn sorted(&self, u: usize) -> Vec<(u32, f32)> {
        sorted_neighbors(self.ids(u), self.dists(u))
    }

    /// All filled (directed) edges `(u, v, dist)`.
    pub fn edges(&self) -> impl Iterator<Item = (u32, u32, f32)> + '_ {
        (0..self.n).flat_map(move |u| {
            self.ids(u)
                .iter()
                .zip(self.dists(u))
                .filter(|(&v, _)| v != EMPTY_ID)
                .map(move |(&v, &d)| (u as u32, v, d))
        })
    }

    /// Relabel and physically reorder under permutation `sigma`
    /// (σ: old id → new id), matching a data-matrix reorder by σ⁻¹
    /// (paper §3.2: after the greedy heuristic, *everything* — data and
    /// graph — moves to the new layout).
    pub fn apply_permutation(&self, sigma: &[u32]) -> Self {
        assert_eq!(sigma.len(), self.n);
        let mut out = Self::new(self.n, self.k);
        for u in 0..self.n {
            let nu = sigma[u] as usize;
            let src = u * self.k..(u + 1) * self.k;
            let dst = nu * self.k..(nu + 1) * self.k;
            out.dists[dst.clone()].copy_from_slice(&self.dists[src.clone()]);
            out.flags[dst.clone()].copy_from_slice(&self.flags[src.clone()]);
            for (o, &v) in out.ids[dst].iter_mut().zip(&self.ids[src]) {
                *o = if v == EMPTY_ID { EMPTY_ID } else { sigma[v as usize] };
            }
            out.filled[nu] = self.filled[u];
            out.fwd_new[nu] = self.fwd_new[u];
            out.rev_new[nu] = self.rev_new[u];
            out.rev_old[nu] = self.rev_old[u];
        }
        out
    }

    /// Verify internal consistency (tests / debug builds): heap property
    /// per node, all counters exact, no self-edges, no duplicates.
    pub fn validate(&self) -> Result<(), String> {
        let mut rev_new = vec![0u32; self.n];
        let mut rev_old = vec![0u32; self.n];
        for u in 0..self.n {
            let ids = self.ids(u);
            let dists = self.dists(u);
            let flags = self.flags(u);
            for i in 1..self.k {
                if dists[(i - 1) / 2] < dists[i] {
                    return Err(format!("node {u}: heap violation at {i}"));
                }
            }
            let mut seen = std::collections::HashSet::new();
            let mut filled = 0u16;
            let mut fwd_new = 0u16;
            for ((&v, &d), &f) in ids.iter().zip(dists).zip(flags) {
                if v == EMPTY_ID {
                    if d != f32::INFINITY {
                        return Err(format!("node {u}: empty slot with finite dist"));
                    }
                    continue;
                }
                filled += 1;
                if v as usize == u {
                    return Err(format!("node {u}: self edge"));
                }
                if v as usize >= self.n {
                    return Err(format!("node {u}: id {v} out of range"));
                }
                if !seen.insert(v) {
                    return Err(format!("node {u}: duplicate neighbor {v}"));
                }
                if f {
                    fwd_new += 1;
                    rev_new[v as usize] += 1;
                } else {
                    rev_old[v as usize] += 1;
                }
            }
            if filled != self.filled[u] {
                return Err(format!("node {u}: filled counter {} ≠ {filled}", self.filled[u]));
            }
            if fwd_new != self.fwd_new[u] {
                return Err(format!("node {u}: fwd_new counter {} ≠ {fwd_new}", self.fwd_new[u]));
            }
        }
        if rev_new != self.rev_new {
            return Err("rev_new counters out of sync".to_string());
        }
        if rev_old != self.rev_old {
            return Err("rev_old counters out of sync".to_string());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{check, Config};

    #[test]
    fn push_and_counters() {
        let mut g = KnnGraph::new(5, 2);
        assert!(g.push(0, 1, 1.0, true));
        assert!(g.push(0, 2, 2.0, true));
        assert_eq!(g.rev_new[1], 1);
        assert_eq!(g.rev_new[2], 1);
        assert_eq!(g.new_size(0), 2); // two flagged forward
        // 3 closer than worst (2.0): evicts 2
        assert!(g.push(0, 3, 1.5, true));
        assert_eq!(g.rev_new[2], 0);
        assert_eq!(g.rev_new[3], 1);
        // worse than worst: rejected
        assert!(!g.push(0, 4, 9.0, true));
        g.validate().unwrap();
    }

    #[test]
    fn clear_flag_moves_new_to_old() {
        let mut g = KnnGraph::new(4, 2);
        g.push(0, 1, 1.0, true);
        g.push(0, 2, 2.0, true);
        assert_eq!(g.new_size(1), 1);
        assert_eq!(g.old_size(1), 0);
        let slot = g.ids(0).iter().position(|&v| v == 1).unwrap();
        g.clear_flag(0, slot);
        assert_eq!(g.rev_new[1], 0);
        assert_eq!(g.rev_old[1], 1);
        assert_eq!(g.fwd_new[0], 1);
        // idempotent
        g.clear_flag(0, slot);
        assert_eq!(g.rev_old[1], 1);
        g.validate().unwrap();
    }

    #[test]
    fn prop_random_ops_keep_counters_exact() {
        check(Config::cases(60), "graph counters exact", |g| {
            let n = g.usize_in(3..40);
            let k = g.usize_in(1..8);
            let mut kg = KnnGraph::new(n, k);
            for _ in 0..300 {
                if g.bool(0.8) {
                    let u = g.usize_in(0..n);
                    let v = g.u32_in(0..n as u32);
                    if v as usize == u {
                        continue;
                    }
                    kg.push(u, v, g.f32_unit() * 10.0, g.bool(0.7));
                } else {
                    let u = g.usize_in(0..n);
                    let i = g.usize_in(0..k);
                    if kg.ids(u)[i] != EMPTY_ID {
                        kg.clear_flag(u, i);
                    }
                }
            }
            kg.validate().is_ok()
        });
    }

    #[test]
    fn permutation_preserves_structure() {
        check(Config::cases(40), "permutation preserves edges", |g| {
            let n = g.usize_in(4..30);
            let k = 3.min(n - 1);
            let mut kg = KnnGraph::new(n, k);
            for _ in 0..100 {
                let u = g.usize_in(0..n);
                let v = g.u32_in(0..n as u32);
                if v as usize != u {
                    kg.push(u, v, g.f32_unit(), g.bool(0.5));
                }
            }
            let sigma = g.permutation(n);
            let pg = kg.apply_permutation(&sigma);
            if pg.validate().is_err() {
                return false;
            }
            // edge (u,v,d) exists iff (σu, σv, d) exists in the image
            let mut orig: Vec<(u32, u32, u32)> = kg
                .edges()
                .map(|(u, v, d)| (sigma[u as usize], sigma[v as usize], d.to_bits()))
                .collect();
            let mut perm: Vec<(u32, u32, u32)> =
                pg.edges().map(|(u, v, d)| (u, v, d.to_bits())).collect();
            orig.sort_unstable();
            perm.sort_unstable();
            orig == perm
        });
    }

    /// Full-strip equality (ids, distance bits, flags) — the "same
    /// graph" notion the parallel build's determinism contract uses.
    fn assert_graphs_identical(a: &KnnGraph, b: &KnnGraph) {
        assert_eq!(a.n(), b.n());
        assert_eq!(a.k(), b.k());
        for u in 0..a.n() {
            assert_eq!(a.ids(u), b.ids(u), "node {u} ids");
            let da: Vec<u32> = a.dists(u).iter().map(|d| d.to_bits()).collect();
            let db: Vec<u32> = b.dists(u).iter().map(|d| d.to_bits()).collect();
            assert_eq!(da, db, "node {u} dists");
            assert_eq!(a.flags(u), b.flags(u), "node {u} flags");
        }
    }

    #[test]
    fn apply_updates_is_independent_of_buffer_order() {
        // a realistic buffer: duplicates, cross-target interleaving,
        // exact distance ties broken by id, and records that lose to
        // better ones for the same target
        let fresh = || {
            let mut g = KnnGraph::new(8, 2);
            g.push(0, 7, 9.0, true);
            g.push(1, 7, 9.0, true);
            g
        };
        let base = vec![
            GraphUpdate { target: 0, nb: 1, dist: 2.0 },
            GraphUpdate { target: 0, nb: 2, dist: 1.0 },
            GraphUpdate { target: 0, nb: 3, dist: 1.0 }, // tie with nb=2 by distance
            GraphUpdate { target: 1, nb: 4, dist: 3.0 },
            GraphUpdate { target: 0, nb: 2, dist: 1.0 }, // duplicate record
            GraphUpdate { target: 1, nb: 5, dist: 0.5 },
            GraphUpdate { target: 1, nb: 6, dist: 4.0 }, // loses: two better fill k=2
        ];
        let mut expect_graph = fresh();
        let mut buf = base.clone();
        let expect_applied = expect_graph.apply_updates(&mut buf);
        assert!(buf.is_empty(), "apply drains the buffer");
        expect_graph.validate().unwrap();

        // every permutation style a worker merge could produce
        let mut shuffles: Vec<Vec<GraphUpdate>> = Vec::new();
        let mut rev = base.clone();
        rev.reverse();
        shuffles.push(rev);
        let mut rot = base.clone();
        rot.rotate_left(3);
        shuffles.push(rot);
        check(Config::cases(20), "apply_updates order-independent", |g| {
            let mut perm = base.clone();
            for i in (1..perm.len()).rev() {
                perm.swap(i, g.usize_in(0..i + 1));
            }
            shuffles.push(perm);
            true
        });
        for (i, shuffle) in shuffles.into_iter().enumerate() {
            let mut graph = fresh();
            let mut buf = shuffle;
            let applied = graph.apply_updates(&mut buf);
            assert_eq!(applied, expect_applied, "shuffle {i} update count");
            assert_graphs_identical(&expect_graph, &graph);
        }
    }

    #[test]
    fn apply_updates_counts_only_successful_pushes() {
        let mut g = KnnGraph::new(4, 2);
        g.push(0, 1, 1.0, true);
        g.push(0, 2, 2.0, true);
        let mut buf = vec![
            GraphUpdate { target: 0, nb: 3, dist: 5.0 }, // worse than worst: rejected
            GraphUpdate { target: 0, nb: 1, dist: 0.5 }, // duplicate neighbor: rejected
            GraphUpdate { target: 0, nb: 3, dist: 0.5 }, // improves: applied
        ];
        assert_eq!(g.apply_updates(&mut buf), 1);
        g.validate().unwrap();
        assert!(g.ids(0).contains(&3));
    }

    #[test]
    fn worst_tracks_heap_root() {
        let mut g = KnnGraph::new(3, 2);
        assert_eq!(g.worst(0), f32::INFINITY);
        g.push(0, 1, 5.0, false);
        g.push(0, 2, 3.0, false);
        assert_eq!(g.worst(0), 5.0);
    }

    #[test]
    fn neighborhood_sizes_split_by_flag() {
        let mut g = KnnGraph::new(4, 3);
        g.push(1, 0, 1.0, true); // 0 gains rev_new
        g.push(2, 0, 1.0, false); // 0 gains rev_old
        g.push(0, 3, 1.0, true); // 0 gains fwd_new
        assert_eq!(g.new_size(0), 2); // fwd_new + rev_new
        assert_eq!(g.old_size(0), 1); // rev_old
        assert_eq!(g.neighborhood_size(0), 3);
        assert_eq!(g.reverse_degree(0), 2);
    }

    #[test]
    fn edges_iterator_counts() {
        let mut g = KnnGraph::new(4, 2);
        g.push(0, 1, 1.0, false);
        g.push(1, 0, 1.0, false);
        g.push(2, 3, 2.0, false);
        assert_eq!(g.edges().count(), 3);
    }
}
