//! Table 2 — real-world runtimes: MNIST and Audio.
//!
//! Paper (k=20, squared L2):
//!
//! |                  | MNIST  | Audio  |
//! |------------------|--------|--------|
//! | blocked          | 12.12s | 4.78s  |
//! | greedyclustering | 11.45s | 4.53s  |
//! | PyNNDescent      | 24.41s | 14.47s |
//!
//! Claims: greedy reordering wins even on real data where the clustered
//! assumption fails; the optimized implementation beats the
//! PyNNDescent-profile baseline clearly on both datasets. Our baseline
//! is a Rust port of PyNNDescent's algorithmic profile (heap selection,
//! per-pair scalar distances — see baseline::pynnd), so the measured gap
//! is a *lower bound* on the paper's (which includes numba overhead).
//!
//! Datasets: real MNIST IDX file if present under data/, otherwise the
//! MNIST-like substitute; Audio-like generator (DESIGN.md §4).
//!
//! Run: `cargo bench --bench bench_realworld` (subsampled)
//!      `KNNG_BENCH_FULL=1 ...` (full 70k/54k, several minutes)

use knng::baseline::pynnd::PyNndBaseline;
use knng::bench::{full_scale, measure_once, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::config::DatasetSpec;
use knng::dataset::from_spec;
use knng::nndescent::{NnDescent, Params};

fn main() {
    let (n_mnist, n_audio) = if full_scale() { (70_000, 54_387) } else { (8_000, 8_000) };
    println!("Table 2 — real-world runtimes (k=20), MNIST n={n_mnist}, Audio n={n_audio}");

    let mnist = from_spec(&DatasetSpec::Mnist { n: n_mnist, path: None, seed: 0x3A15 }).unwrap();
    let audio = from_spec(&DatasetSpec::Audio { n: n_audio, dim: 192, seed: 0xAD10 }).unwrap();
    println!("datasets: {} ({}×{}), {} ({}×{})", mnist.name, mnist.n(), mnist.dim(), audio.name, audio.n(), audio.dim());

    let blocked = Params::default()
        .with_k(20)
        .with_seed(2)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked);

    let mut rows: Vec<(String, f64, f64)> = Vec::new();
    for (tag, reorder) in [("blocked", false), ("greedyclustering", true)] {
        let p = blocked.clone().with_reorder(reorder);
        let (_, tm) = measure_once(|| NnDescent::new(p.clone()).build(&mnist.data).unwrap());
        let (_, ta) = measure_once(|| NnDescent::new(p.clone()).build(&audio.data).unwrap());
        rows.push((tag.to_string(), tm, ta));
    }
    {
        let b = PyNndBaseline::default().with_k(20).with_seed(2);
        let (_, tm) = measure_once(|| b.build(&mnist.data));
        let (_, ta) = measure_once(|| b.build(&audio.data));
        rows.push(("pynnd-baseline".to_string(), tm, ta));
    }

    let mut table = Table::new("table2_realworld", &["variant", "MNIST_secs", "Audio_secs"]);
    for (tag, tm, ta) in &rows {
        table.row(&[tag.clone(), format!("{tm:.2}"), format!("{ta:.2}")]);
    }
    table.finish();

    let speedup_mnist = rows[2].1 / rows[1].1;
    let speedup_audio = rows[2].2 / rows[1].2;
    println!(
        "\ngreedy vs baseline: MNIST {speedup_mnist:.2}× (paper 2.13×), Audio {speedup_audio:.2}× (paper 3.19×)"
    );
    println!(
        "greedy vs blocked: MNIST {:.2}% (paper 5.5%), Audio {:.2}% (paper 5.2%)",
        (rows[0].1 / rows[1].1 - 1.0) * 100.0,
        (rows[0].2 / rows[1].2 - 1.0) * 100.0
    );
}
