//! Ablation (paper §5 future work) — alternative reordering heuristics.
//!
//! Compares Algorithm 1 (greedy) against BFS / DFS / degree orderings on
//! three axes: heuristic cost, cluster-recovery quality (Fig 4 metric),
//! and end-to-end build-time effect (Fig 5 metric).
//!
//! Run: `cargo bench --bench bench_reorder_ablation`

use knng::bench::{fmt_secs, full_scale, measure_once, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::metrics::window::{cluster_window_fractions, mean_max_fraction};
use knng::nndescent::reorder_alt::ReorderKind;
use knng::nndescent::{NnDescent, Params};

fn main() {
    let n = if full_scale() { 16_384 } else { 8_192 };
    let clusters = 16;
    println!("reordering-heuristic ablation, Synthetic Clustered n={n} c={clusters} d=8 k=20");

    let (data, labels) = SynthClustered::new(n, 8, clusters, 0xAB1A).generate_labeled();
    let base = Params::default()
        .with_k(20)
        .with_seed(6)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked)
        .with_max_iters(2);

    // early approximation shared by all heuristics
    let early = NnDescent::new(base).build(&data).unwrap();

    let mut table = Table::new(
        "reorder_ablation",
        &["heuristic", "perm_secs", "cluster_contiguity", "e2e_build_secs"],
    );
    // no-reorder baseline row
    let full_params = |reorder: bool| {
        Params::default()
            .with_k(20)
            .with_seed(6)
            .with_selection(SelectionKind::Turbo)
            .with_compute(ComputeKind::Blocked)
            .with_reorder(reorder)
    };
    let (_, plain_secs) =
        measure_once(|| NnDescent::new(full_params(false)).build(&data).unwrap());
    table.row(&["(none)".into(), "-".into(), format!("{:.3}", 1.0 / clusters as f64), format!("{plain_secs:.3}")]);

    for kind in ReorderKind::ALL {
        let (perm, perm_secs) = measure_once(|| kind.permutation(&early.graph));
        perm.validate().unwrap();
        let fr = cluster_window_fractions(&perm.inv, &labels, clusters, n / 8, n / 64);
        let contiguity = mean_max_fraction(&fr);

        // e2e effect: run the full build, manually applying this
        // heuristic's permutation via a pre-permuted dataset (the driver
        // hook only knows greedy; for the ablation we emulate by feeding
        // permuted data, which has the same locality effect).
        let permuted = data.permuted(&perm.inv);
        let (_, e2e) =
            measure_once(|| NnDescent::new(full_params(false)).build(&permuted).unwrap());

        table.row(&[
            kind.name().into(),
            fmt_secs(perm_secs),
            format!("{contiguity:.3}"),
            format!("{e2e:.3}"),
        ]);
    }
    table.finish();
    println!(
        "\nreading: contiguity 1.0 = perfectly grouped clusters, {:.3} = random; \
         e2e column shows the locality payoff of pre-permuted input",
        1.0 / clusters as f64
    );
}
