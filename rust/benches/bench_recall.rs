//! §2 recall validation — "our implementation achieved a recall of over
//! 99% on all examined datasets" (paper, k=20).
//!
//! Recall is measured against exact brute-force ground truth on a
//! deterministic sample of query nodes (full truth at CI sizes).
//! Also fits the empirical distance-evaluation exponent against Dong et
//! al.'s reported O(n^1.14).
//!
//! Run: `cargo bench --bench bench_recall`

use knng::baseline::brute::brute_force_knn_sampled;
use knng::bench::{full_scale, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::config::DatasetSpec;
use knng::dataset::from_spec;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};
use knng::util::stats::powerlaw_fit;

fn main() {
    let scale = if full_scale() { 4 } else { 1 };
    let k = 20;
    println!("recall validation (k={k}) + empirical cost exponent");

    // (spec, recall gate). The iid Gaussian at d=256 has maximal
    // intrinsic dimension — the known hard case for NN-Descent (Dong et
    // al. report recall degrading with intrinsic dim); it is reported
    // but gated loosely. The paper's ≥99% claim concerns its structured
    // datasets (clustered, MNIST, audio) and low-d synthetics.
    let specs: Vec<(DatasetSpec, f64)> = vec![
        (DatasetSpec::Gaussian { n: 4096 * scale, dim: 8, single: true, seed: 1 }, 0.97),
        // (recall on iid high-d degrades with n too: ≈0.68 at n=4096,
        // ≈0.43 at n=16384 — reported, loosely gated)
        (DatasetSpec::Gaussian { n: 4096 * scale, dim: 256, single: false, seed: 2 }, 0.35),
        (DatasetSpec::Clustered { n: 4096 * scale, dim: 8, clusters: 16, seed: 3 }, 0.97),
        (DatasetSpec::Mnist { n: 4000 * scale, path: None, seed: 4 }, 0.97),
        (DatasetSpec::Audio { n: 4000 * scale, dim: 192, seed: 5 }, 0.90),
    ];

    let mut table = Table::new("recall_all_datasets", &["dataset", "n", "dim", "recall", "dist_evals"]);
    for (spec, gate) in &specs {
        let ds = from_spec(spec).unwrap();
        for reorder in [false, true] {
            let params = Params::default()
                .with_k(k)
                .with_seed(9)
                .with_selection(SelectionKind::Turbo)
                .with_compute(ComputeKind::Blocked)
                .with_reorder(reorder);
            let result = NnDescent::new(params).build(&ds.data).unwrap();
            let truth = brute_force_knn_sampled(&ds.data, k, 400, 77);
            let recall = recall_against_truth(&result, &truth);
            table.row(&[
                format!("{}{}", ds.name, if reorder { "+greedy" } else { "" }),
                ds.n().to_string(),
                ds.dim().to_string(),
                format!("{recall:.4}"),
                result.stats.dist_evals.to_string(),
            ]);
            assert!(recall > *gate, "{}: recall {recall} below gate {gate}", ds.name);
        }
    }
    table.finish();

    // empirical cost exponent (Dong et al.: ~n^1.14)
    let mut ns = Vec::new();
    let mut evals = Vec::new();
    for &n in &[2000usize, 4000, 8000, 16_000] {
        let ds = from_spec(&DatasetSpec::Gaussian { n, dim: 8, single: true, seed: 6 }).unwrap();
        let params = Params::default().with_k(k).with_seed(10);
        let r = NnDescent::new(params).build(&ds.data).unwrap();
        ns.push(n as f64);
        evals.push(r.stats.dist_evals as f64);
    }
    let (_, b) = powerlaw_fit(&ns, &evals);
    println!("\nempirical distance-eval cost: O(n^{b:.3}) (Dong et al. report n^1.14)");
}
