//! Ablation (ours) — PJRT-offloaded compute step vs native blocked.
//!
//! Quantifies what the three-layer composition costs/buys on this CPU
//! testbed: the AOT Pallas kernel (via PJRT) against the native Rust
//! 5×5 blocked kernel, per dimension, at the compute step's natural
//! batch shape (one candidate set ≤ 50 per call) and at the tile-scan
//! shape (bulk brute force, where the XLA kernel amortizes dispatch).
//!
//! Requires `make artifacts`.
//!
//! Run: `cargo bench --bench bench_pjrt`

use knng::bench::{fmt_secs, full_scale, measure, Table};
use knng::cachesim::trace::NoTracer;
use knng::dataset::synth::SynthGaussian;
use knng::distance::blocked::{pairwise_blocked, PairwiseBuf};
use knng::nndescent::compute::PairwiseEngine;
use knng::runtime::{PjrtEngine, TileScanner};
use knng::util::stats::Summary;

fn main() {
    let sets = if full_scale() { 400 } else { 100 };
    let m = 40; // candidate-set size (new+old at defaults)
    println!("PJRT vs native blocked — per-candidate-set dispatch ({sets} sets of {m})");

    let mut engine = match PjrtEngine::open("artifacts") {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: {e:#} (run `make artifacts`)");
            return;
        }
    };

    let mut table = Table::new(
        "pjrt_vs_native",
        &["dim", "native_blocked", "pjrt_pallas", "pjrt_overhead"],
    );
    for dim in [64usize, 256, 784] {
        let data = SynthGaussian::single(m * 4, dim, dim as u64).generate();
        let ids: Vec<u32> = (0..m as u32).collect();
        let mut buf = PairwiseBuf::with_capacity(64);

        let native = Summary::of(&measure(5, || {
            for _ in 0..sets {
                pairwise_blocked(&data, &ids, &mut buf);
            }
        }))
        .median;
        let pjrt = Summary::of(&measure(3, || {
            for _ in 0..sets {
                engine.pairwise(&data, &ids, ids.len(), &mut buf, &mut NoTracer);
            }
        }))
        .median;
        table.row(&[
            dim.to_string(),
            fmt_secs(native / sets as f64),
            fmt_secs(pjrt / sets as f64),
            format!("{:.1}×", pjrt / native),
        ]);
    }
    table.finish();

    // bulk shape: tile scan (128×1024) where dispatch amortizes
    println!("\nPJRT tile-scan (bulk brute-force shape, 128×1024):");
    let mut table = Table::new("pjrt_tilescan", &["dim", "pjrt_per_tile", "native_per_tile", "ratio"]);
    for dim in [64usize, 256, 784] {
        let data = SynthGaussian::single(2048, dim, 3).generate();
        let queries: Vec<u32> = (0..128).collect();
        let corpus: Vec<u32> = (128..128 + 1024).collect();
        match TileScanner::open("artifacts", 128, 1024, data.dim_pad()) {
            Ok(mut scanner) => {
                let pjrt = Summary::of(&measure(3, || {
                    scanner.scan(&data, &queries, &corpus).unwrap()
                }))
                .median;
                // native equivalent: 128×1024 pair-at-a-time blocked-ish
                let native = Summary::of(&measure(3, || {
                    let mut acc = 0f32;
                    for &q in &queries {
                        for &c in &corpus {
                            acc += knng::distance::sq_l2_unrolled(
                                data.row(q as usize),
                                data.row(c as usize),
                            );
                        }
                    }
                    acc
                }))
                .median;
                table.row(&[
                    dim.to_string(),
                    fmt_secs(pjrt),
                    fmt_secs(native),
                    format!("{:.2}×", pjrt / native),
                ]);
            }
            Err(e) => {
                eprintln!("  d={dim}: skipped ({e:#})");
            }
        }
    }
    table.finish();
    println!("\nexpectation: per-set dispatch overhead dominates small batches; bulk tiles amortize");
}
