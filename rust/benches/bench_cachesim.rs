//! Table 1 — simulated LL cache misses with/without the reordering
//! heuristic (cachegrind substitute; see DESIGN.md §4).
//!
//! Paper (Synthetic Clustered, n=131'072, 16 clusters):
//!
//! | config                  | LL read misses | LL write misses |
//! |-------------------------|----------------|-----------------|
//! | no-heuristic  (d=8)     | 122'150'286    | 14'777'070      |
//! | greedyheuristic (d=8)   |  69'653'838    | 12'328'994      |
//! | no-heuristic  (d=256)   | 450'209'609    | 20'438'131      |
//!
//! Claims to reproduce: (1) greedy nearly halves LL read misses at d=8;
//! (2) d ×32 raises LL read misses by a much smaller factor (spatial
//! locality within rows).
//!
//! Default size is CI-scale (n=16'384, misses scale accordingly) with a
//! proportionally shrunken LL cache so the working-set:cache ratio — the
//! quantity the claims rest on — matches the paper's. `KNNG_BENCH_FULL=1`
//! runs the paper's exact n and cache geometry (minutes).

use knng::bench::{fmt_count, full_scale, Table};
use knng::cachesim::{CacheTracer, Geometry};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::nndescent::compute::NativeEngine;
use knng::nndescent::{NnDescent, Params};

fn run(n: usize, d: usize, reorder: bool, geom: Geometry) -> (u64, u64) {
    let (data, _) = SynthClustered::new(n, d, 16, 0x7AB1).generate_labeled();
    let params = Params::default()
        .with_k(20)
        .with_seed(1)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked)
        .with_reorder(reorder);
    let mut tracer = CacheTracer::new(geom);
    let mut engine = NativeEngine::new(ComputeKind::Blocked);
    let _ = NnDescent::new(params).build_with_engine(&data, &mut engine, &mut tracer);
    let s = tracer.stats();
    (s.ll_read_misses, s.ll_write_misses)
}

fn main() {
    let (n, geom) = if full_scale() {
        (131_072, Geometry::default()) // paper: 12 MiB LL
    } else {
        // n/8 with a 1 MiB LL keeps the paper's marginal working-set:LL
        // ratio (8 MiB data vs 12 MiB LL → 1 MiB data vs 1 MiB LL);
        // measured greedy ratio 0.55 vs paper's 0.57 at this scale.
        (16_384, Geometry { ll_size: 1 << 20, ..Geometry::default() })
    };
    println!(
        "Table 1 — simulated cachegrind, Synthetic Clustered n={} c=16, LL={} KiB",
        fmt_count(n as u64),
        geom.ll_size >> 10
    );

    let mut table =
        Table::new("table1_cachesim", &["config", "LL_read_misses", "LL_write_misses"]);
    let (r1, w1) = run(n, 8, false, geom);
    table.row(&["no-heuristic (d=8)".into(), fmt_count(r1), fmt_count(w1)]);
    let (r2, w2) = run(n, 8, true, geom);
    table.row(&["greedyheuristic (d=8)".into(), fmt_count(r2), fmt_count(w2)]);
    let (r3, w3) = run(n, 256, false, geom);
    table.row(&["no-heuristic (d=256)".into(), fmt_count(r3), fmt_count(w3)]);
    table.finish();

    println!("\ngreedy/no-heuristic LL read-miss ratio (d=8): {:.2} (paper: 0.57)", r2 as f64 / r1 as f64);
    println!("d=256 / d=8 LL read-miss factor: {:.1}× for 32× the work (paper: 3.7×)", r3 as f64 / r1 as f64);
    println!("paper reference: greedy nearly halves LL read misses; d=256 misses grow ≪ 32×");
}
