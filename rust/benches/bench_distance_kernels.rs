//! Distance-kernel microbenchmark (supports §3.3's tags in isolation):
//! scalar vs unrolled (l2intrinsics/mem-align) vs 5×5 blocked, per
//! dimension — plus effective flops/cycle so the kernel numbers can be
//! placed on the roofline by hand.
//!
//! Run: `cargo bench --bench bench_distance_kernels`

use knng::bench::{fmt_secs, full_scale, measure, Table};
use knng::dataset::synth::SynthGaussian;
use knng::distance::blocked::{pairwise_blocked, pairwise_flat, PairwiseBuf};
use knng::util::stats::Summary;
use knng::util::timer::DEFAULT_NOMINAL_HZ;

fn main() {
    let m = 50; // paper's candidate-set cap
    let reps = if full_scale() { 9 } else { 5 };
    let sets = if full_scale() { 2000 } else { 400 };
    println!("distance kernels: {sets} candidate sets of {m} vectors per measurement");

    let mut table = Table::new(
        "distance_kernels",
        &["dim", "scalar", "unrolled", "blocked", "blocked_speedup", "blocked_flops_per_cycle"],
    );
    for dim in [8usize, 64, 192, 256, 784, 1568] {
        let data = SynthGaussian::single(m * 8, dim, dim as u64).generate();
        // rotate through different id sets so data doesn't stay L1-hot
        let id_sets: Vec<Vec<u32>> = (0..8)
            .map(|s| (0..m as u32).map(|i| (i * 8 + s) % (m as u32 * 8)).collect())
            .collect();
        let mut buf = PairwiseBuf::with_capacity(m);

        let mut run = |f: &mut dyn FnMut(&[u32], &mut PairwiseBuf) -> u64| {
            let samples = measure(reps, || {
                let mut evals = 0u64;
                for s in 0..sets {
                    evals += f(&id_sets[s % 8], &mut buf);
                }
                evals
            });
            Summary::of(&samples).median
        };

        let t_scalar = run(&mut |ids, buf| pairwise_flat(&data, ids, buf, false));
        let t_unrolled = run(&mut |ids, buf| pairwise_flat(&data, ids, buf, true));
        let t_blocked = run(&mut |ids, buf| pairwise_blocked(&data, ids, buf));

        let evals = (sets * m * (m - 1) / 2) as f64;
        let flops = evals * (3.0 * dim as f64 - 1.0);
        let fpc = flops / (t_blocked * DEFAULT_NOMINAL_HZ);
        table.row(&[
            dim.to_string(),
            fmt_secs(t_scalar),
            fmt_secs(t_unrolled),
            fmt_secs(t_blocked),
            format!("{:.2}× vs unrolled", t_unrolled / t_blocked),
            format!("{fpc:.2}"),
        ]);
    }
    table.finish();
    println!("\npaper reference: blocking pays off increasingly with dimension (Fig 7)");
}
