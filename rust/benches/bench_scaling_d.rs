//! Fig 7 — performance [flops/cycle] vs dimension at n=16'384.
//!
//! Paper: Synthetic *Single* Gaussian, n fixed at 16'384, d from 8 to
//! 3144; `turbosampling` only gains 3.52× over the d sweep while
//! `blocked` gains 8.90× — the high-dim optimizations need dimension to
//! pay off, and the implementation crosses from memory- to
//! compute-bound.
//!
//! Run: `cargo bench --bench bench_scaling_d`
//!      `KNNG_BENCH_FULL=1` for the paper's full d range.

use knng::bench::{full_scale, measure_once, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::nndescent::{NnDescent, Params};
use knng::util::timer::DEFAULT_NOMINAL_HZ;

fn main() {
    let n = if full_scale() { 16_384 } else { 4_096 };
    let dims: Vec<usize> = if full_scale() {
        vec![8, 72, 136, 264, 520, 1032, 1544, 2056, 3144]
    } else {
        vec![8, 64, 256, 784]
    };
    println!("Fig 7 — perf vs d at n={n} (Synthetic Single Gaussian, k=20)");

    let variants: Vec<(&str, ComputeKind)> = vec![
        ("turbosampling", ComputeKind::Scalar),
        ("l2intrinsics+memalign", ComputeKind::Unrolled),
        ("blocked", ComputeKind::Blocked),
    ];

    let mut table =
        Table::new("fig7_scaling_d", &["variant", "dim", "secs", "flops_per_cycle"]);
    let mut first_last: std::collections::HashMap<&str, (f64, f64)> = Default::default();
    for &d in &dims {
        let data = SynthGaussian::single(n, d, 0xF17).generate();
        for (tag, compute) in &variants {
            let params = Params::default()
                .with_k(20)
                .with_seed(7)
                .with_selection(SelectionKind::Turbo)
                .with_compute(*compute);
            let (result, secs) =
                measure_once(|| NnDescent::new(params.clone()).build(&data).unwrap());
            let fpc = result.stats.flops() as f64 / (secs * DEFAULT_NOMINAL_HZ);
            let e = first_last.entry(tag).or_insert((fpc, fpc));
            e.1 = fpc;
            table.row(&[tag.to_string(), d.to_string(), format!("{secs:.3}"), format!("{fpc:.3}")]);
        }
    }
    table.finish();

    println!("\nd-sweep gain (last dim / first dim flops-per-cycle):");
    for (tag, _) in &variants {
        let (first, last) = first_last[tag];
        println!("  {tag:<22} {:.2}×", last / first);
    }
    println!("paper reference: turbosampling 3.52×, blocked 8.90× (d=8 → d=3144)");
}
