//! §4.1 — Selection-step speedups.
//!
//! Paper: on Synthetic Gaussian (n=16'384, d=8, k=20), the fused
//! heap-based sampling is ≈16× faster than the naive three-pass
//! implementation, and turbosampling adds ≈1.12× on top. Measured in
//! *runtime* (not flops/cycle) because the three versions do slightly
//! different numbers of comparisons — same protocol as the paper.
//!
//! Each measured repetition runs on a fresh clone of the same
//! post-init graph (selection mutates flags); the clone cost is
//! measured separately and subtracted from every row.
//!
//! Run: `cargo bench --bench bench_selection`
//! Paper-scale sizes: `KNNG_BENCH_FULL=1 cargo bench --bench bench_selection`

use knng::bench::{fmt_secs, full_scale, measure, Table};
use knng::cachesim::trace::NoTracer;
use knng::config::schema::SelectionKind;
use knng::dataset::synth::SynthGaussian;
use knng::graph::KnnGraph;
use knng::nndescent::candidates::CandidateLists;
use knng::nndescent::init::init_random;
use knng::nndescent::selection::Selector;
use knng::nndescent::Params;
use knng::util::counters::FlopCounter;
use knng::util::rng::Pcg64;
use knng::util::stats::Summary;

fn main() {
    let n = if full_scale() { 16_384 } else { 4_096 };
    let (d, k) = (8, 20);
    let reps = if full_scale() { 7 } else { 5 };
    println!("selection-step microbenchmark: n={n} d={d} k={k} (paper §4.1)");

    let data = SynthGaussian::single(n, d, 0xBEEF).generate();
    let params = Params::default().with_k(k).with_seed(7);
    let cap = params.cand_cap();
    let mut graph = KnnGraph::new(n, k);
    let mut rng = Pcg64::new(7);
    init_random(&mut graph, &data, &mut rng, &mut FlopCounter::new(d), &mut NoTracer);

    // clone-only baseline, subtracted from each selector's time
    let clone_cost = Summary::of(&measure(reps, || graph.clone())).median;

    let mut table = Table::new(
        "selection_step",
        &["selector", "median_select", "speedup_vs_naive", "speedup_vs_heap"],
    );
    let mut medians: Vec<f64> = Vec::new();
    for kind in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
        let mut selector = Selector::new(kind, n, cap);
        let mut out = CandidateLists::new(n, cap);
        let samples = measure(reps, || {
            let mut g = graph.clone();
            let mut r = Pcg64::new(99);
            selector.select(&mut g, &mut r, &mut out, &mut NoTracer);
            out.total()
        });
        let median = (Summary::of(&samples).median - clone_cost).max(1e-9);
        medians.push(median);
        table.row(&[
            kind.name().to_string(),
            fmt_secs(median),
            format!("{:.2}×", medians[0] / median),
            if medians.len() >= 2 { format!("{:.2}×", medians[1] / median) } else { "-".into() },
        ]);
    }
    table.finish();

    println!("\npaper reference: heap ≈16× over naive, turbo ≈1.12× over heap");
}
