//! Query-serving throughput (ours) — queries/sec vs batch size and `ef`
//! through `GraphIndex::search_batch`, which tiles query×corpus distance
//! evaluations through the 5×5 blocked kernel and reuses per-query
//! scratch, against the sequential single-query path. The batched and
//! sequential paths return identical results (bit-equal kernels), so
//! this measures pure serving-layer overhead/locality.
//!
//! Run: `cargo bench --bench bench_query_throughput`

use knng::bench::{full_scale, measure_once, Table};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::nndescent::{NnDescent, Params};
use knng::search::{IndexBundle, SearchParams};

fn main() {
    let scale = if full_scale() { 4 } else { 1 };
    let n = 16_384 * scale;
    let n_queries = 1024 * scale;
    let (dim, k) = (64, 10);

    println!("query throughput — corpus n={n} d={dim}, {n_queries} held-out queries, k={k}");

    // corpus + held-out queries from the same distribution
    let (all, _) = SynthClustered::new(n + n_queries, dim, 32, 0xB47C4).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    let queries_flat: Vec<f32> =
        (n..n + n_queries).flat_map(|i| all.row_logical(i).to_vec()).collect();

    // build once (reordered — the bundle keeps the working layout, so
    // serving inherits the locality win) and serve through the bundle
    // path, exactly as `knng build --save-index` + `knng query --index`
    let params = Params::default().with_k(20).with_seed(7).with_reorder(true);
    let (result, build_secs) = measure_once(|| NnDescent::new(params.clone()).build(&corpus));
    println!("graph built in {build_secs:.2}s ({} iterations)", result.iterations);
    let (index, _reordering, _) =
        IndexBundle::from_build(&corpus, &result, &params).into_index();

    let mut table = Table::new(
        "query_throughput",
        &["ef", "batch", "qps", "evals/query", "expansions/query", "vs seq"],
    );
    for ef in [32usize, 64, 128] {
        let sp = SearchParams { ef, ..Default::default() };

        // sequential baseline over the full query set
        let (seq_evals, seq_secs) = measure_once(|| {
            let mut evals = 0u64;
            for qi in 0..n_queries {
                let q = &queries_flat[qi * dim..(qi + 1) * dim];
                let (_, stats) = index.search(q, k, &sp);
                evals += stats.dist_evals;
            }
            evals
        });
        let seq_qps = n_queries as f64 / seq_secs;
        table.row(&[
            format!("{ef}"),
            "seq".into(),
            format!("{seq_qps:.0}"),
            format!("{:.0}", seq_evals as f64 / n_queries as f64),
            "-".into(),
            "1.00x".into(),
        ]);

        for batch in [1usize, 16, 64, 256, 1024] {
            let batch = batch.min(n_queries);
            // serve the query set in `batch`-sized slices
            let (agg, secs) = measure_once(|| {
                let mut total = (0u64, 0u64); // (evals, expansions)
                let mut served = 0usize;
                while served < n_queries {
                    let b = batch.min(n_queries - served);
                    let qm = AlignedMatrix::from_rows(
                        b,
                        dim,
                        &queries_flat[served * dim..(served + b) * dim],
                    );
                    let (_, stats) = index.search_batch(&qm, k, &sp);
                    total.0 += stats.dist_evals;
                    total.1 += stats.expansions;
                    served += b;
                }
                total
            });
            let qps = n_queries as f64 / secs;
            table.row(&[
                format!("{ef}"),
                format!("{batch}"),
                format!("{qps:.0}"),
                format!("{:.0}", agg.0 as f64 / n_queries as f64),
                format!("{:.1}", agg.1 as f64 / n_queries as f64),
                format!("{:.2}x", qps / seq_qps),
            ]);
        }
    }
    table.finish();
}
