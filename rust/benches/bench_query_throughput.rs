//! Query-serving throughput (ours) — queries/sec vs batch size and `ef`
//! through the `api` facade's `Searcher` trait: the single `Index`
//! (batched path tiles query×corpus evaluations through the 5×5 blocked
//! kernel and reuses per-query scratch) against the sequential
//! single-query path, plus the `ShardedSearcher` (S=4) fanning each
//! batch across four independently-built shards and merging global
//! top-k. A recall column (vs sampled brute force) shows what sharding
//! costs in quality — gated at ≤ 0.02 below the single index on this
//! clustered config.
//!
//! Run: `cargo bench --bench bench_query_throughput`

use knng::api::{
    FrontConfig, IndexBuilder, KMeans, Searcher, ServeFront, ShardPool, ShardedSearcher,
};
use knng::bench::{full_scale, measure_once, write_bench_json, Json, Table};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::dispatch;
use knng::distance::KernelWidth;
use knng::metrics::recall::{exact_neighbor_ids, recall_vs_exact};
use knng::nndescent::Params;
use knng::search::SearchParams;

fn main() {
    println!("kernel dispatch: {}", dispatch::describe());
    let scale = if full_scale() { 4 } else { 1 };
    let n = 16_384 * scale;
    let n_queries = 1024 * scale;
    let (dim, k) = (64, 10);

    println!("query throughput — corpus n={n} d={dim}, {n_queries} held-out queries, k={k}");

    // corpus + held-out queries from the same distribution
    let (all, _) = SynthClustered::new(n + n_queries, dim, 32, 0xB47C4).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    let queries_flat: Vec<f32> =
        (n..n + n_queries).flat_map(|i| all.row_logical(i).to_vec()).collect();
    let qmat = AlignedMatrix::from_rows(n_queries, dim, &queries_flat);

    // build once (reordered — the Index keeps the working layout, so
    // serving inherits the locality win), exactly as
    // `knng build --save-index` + `knng query --index`
    let params = Params::default().with_k(20).with_seed(7).with_reorder(true);
    let corpus_for_build = corpus.clone();
    let build_params = params.clone();
    let (mut index, build_secs) = measure_once(move || {
        IndexBuilder::new()
            .data_named(corpus_for_build, "clustered")
            .params(build_params)
            .build()
            .unwrap()
    });
    println!(
        "single index built in {build_secs:.2}s ({} iterations)",
        index.telemetry().unwrap().iterations
    );

    // the sharded comparator: 4 independently-built shards, same params
    let (sharded, shard_secs) =
        measure_once(|| ShardedSearcher::build(&corpus, 4, &params).unwrap());
    println!(
        "sharded searcher built in {shard_secs:.2}s ({} shards of {:?})",
        sharded.shard_count(),
        sharded.shard_sizes()
    );

    // recall gate: sharding may cost at most 0.02 on this clustered config
    let sp_recall = SearchParams::default();
    let sample = 200.min(n_queries);
    let sample_q = AlignedMatrix::from_rows(sample, dim, &queries_flat[..sample * dim]);
    let truth = exact_neighbor_ids(&corpus, &sample_q, k);
    let (single_res, _) = index.search_batch(&sample_q, k, &sp_recall);
    let (sharded_res, _) = sharded.search_batch(&sample_q, k, &sp_recall);
    let single_recall = recall_vs_exact(&single_res, &truth);
    let sharded_recall = recall_vs_exact(&sharded_res, &truth);
    println!(
        "recall@{k} over {sample} queries: single {single_recall:.4}, S=4 {sharded_recall:.4}"
    );
    assert!(
        sharded_recall >= single_recall - 0.02,
        "sharded recall {sharded_recall} dropped more than 0.02 below single {single_recall}"
    );

    let searchers: [(&str, &dyn Searcher); 2] = [("single", &index), ("S=4", &sharded)];
    let mut table = Table::new(
        "query_throughput",
        &["searcher", "ef", "batch", "qps", "evals/query", "expansions/query", "vs seq"],
    );
    for (label, searcher) in searchers {
        for ef in [32usize, 64, 128] {
            let sp = SearchParams { ef, ..Default::default() };

            // sequential baseline over the full query set
            let (seq_evals, seq_secs) = measure_once(|| {
                let mut evals = 0u64;
                for qi in 0..n_queries {
                    let q = &queries_flat[qi * dim..(qi + 1) * dim];
                    let (_, stats) = searcher.search(q, k, &sp);
                    evals += stats.dist_evals;
                }
                evals
            });
            let seq_qps = n_queries as f64 / seq_secs;
            table.row(&[
                label.into(),
                format!("{ef}"),
                "seq".into(),
                format!("{seq_qps:.0}"),
                format!("{:.0}", seq_evals as f64 / n_queries as f64),
                "-".into(),
                "1.00x".into(),
            ]);

            for batch in [16usize, 256, 1024] {
                let batch = batch.min(n_queries);
                // serve the query set in `batch`-sized slices
                let (agg, secs) = measure_once(|| {
                    let mut total = (0u64, 0u64); // (evals, expansions)
                    let mut served = 0usize;
                    while served < n_queries {
                        let b = batch.min(n_queries - served);
                        let qm = AlignedMatrix::from_rows(
                            b,
                            dim,
                            &queries_flat[served * dim..(served + b) * dim],
                        );
                        let (_, stats) = searcher.search_batch(&qm, k, &sp);
                        total.0 += stats.dist_evals;
                        total.1 += stats.expansions;
                        served += b;
                    }
                    total
                });
                let qps = n_queries as f64 / secs;
                table.row(&[
                    label.into(),
                    format!("{ef}"),
                    format!("{batch}"),
                    format!("{qps:.0}"),
                    format!("{:.0}", agg.0 as f64 / n_queries as f64),
                    format!("{:.1}", agg.1 as f64 / n_queries as f64),
                    format!("{:.2}x", qps / seq_qps),
                ]);
            }
        }
    }
    // one full-batch S=4 row is the acceptance artifact; make it easy to
    // eyeball even when the table scrolls
    let sp = SearchParams::default();
    let (_, sstats) = sharded.search_batch(&qmat, k, &sp);
    println!(
        "S=4 full-batch throughput: {:.0} qps over {} queries (ef={}, kernel {})",
        sstats.qps(),
        sstats.queries,
        sp.ef,
        sstats.kernel
    );
    table.finish();

    // ---- per-kernel-width comparison (the dispatch engine's A/B) ----
    // Force each width in turn on the single index's full-batch path,
    // refreshing the corpus norms each time so every row measures
    // exactly what a build/load at that width would serve. Forcing is
    // safe on any CPU (portable SIMD); only speed differs.
    let mut wtable = Table::new(
        "query_throughput_by_kernel",
        &["kernel", "qps", "evals/query", "recall@10", "note"],
    );
    let mut json_rows = Vec::new();
    for width in KernelWidth::ALL {
        dispatch::force(Some(width));
        index.refresh_norms();
        let (res, wstats) = index.search_batch(&qmat, k, &sp);
        let recall = recall_vs_exact(&res[..sample], &truth);
        let note = if width == KernelWidth::W16 && !dispatch::avx512_supported() {
            "no avx512f on this CPU"
        } else {
            ""
        };
        wtable.row(&[
            width.name().into(),
            format!("{:.0}", wstats.qps()),
            format!("{:.0}", wstats.dist_evals_per_query()),
            format!("{recall:.4}"),
            note.into(),
        ]);
        json_rows.push(Json::obj(vec![
            ("kernel", Json::s(width.name())),
            ("qps", Json::Num(wstats.qps())),
            ("evals_per_query", Json::Num(wstats.dist_evals_per_query())),
            ("recall", Json::Num(recall)),
            ("ef", Json::Int(sp.ef as u64)),
            ("batch", Json::Int(n_queries as u64)),
        ]));
    }
    dispatch::force(None);
    index.refresh_norms();

    // sharded S=4 full-batch row at the default width, for trajectory
    json_rows.push(Json::obj(vec![
        ("kernel", Json::s(sstats.kernel)),
        ("qps", Json::Num(sstats.qps())),
        ("evals_per_query", Json::Num(sstats.dist_evals_per_query())),
        ("recall", Json::Num(sharded_recall)),
        ("ef", Json::Int(sp.ef as u64)),
        ("batch", Json::Int(n_queries as u64)),
        ("searcher", Json::s("S=4")),
    ]));
    wtable.finish();

    // ---- thread-per-shard serving (api::serve::ShardPool) ----
    // Full-batch fan-out over the same 4 shards at 1/2/4 worker
    // threads. The pool must stay bit-identical to the inline fan-out
    // at every thread count (asserted here, not just eyeballed); the
    // speedup column shows what threading actually buys on this CPU.
    let (sharded_full, _) = sharded.search_batch(&qmat, k, &sp);
    let mut ttable = Table::new(
        "query_throughput_threaded",
        &["searcher", "threads", "qps", "vs 1 thread", "bit-identical"],
    );
    let mut one_thread_qps = 0.0;
    for threads in [1usize, 2, 4] {
        let pool = ShardPool::new(&sharded, threads).unwrap();
        let (res, pstats) = pool.search_batch(&qmat, k, &sp);
        knng::testing::assert_neighbors_bitwise_eq(
            &sharded_full,
            &res,
            &format!("threads={threads}"),
        );
        if threads == 1 {
            one_thread_qps = pstats.qps();
        }
        ttable.row(&[
            "S=4 pool".into(),
            format!("{threads}"),
            format!("{:.0}", pstats.qps()),
            format!("{:.2}x", pstats.qps() / one_thread_qps.max(1e-12)),
            "yes".into(),
        ]);
        json_rows.push(Json::obj(vec![
            ("kernel", Json::s(pstats.kernel)),
            ("qps", Json::Num(pstats.qps())),
            ("evals_per_query", Json::Num(pstats.dist_evals_per_query())),
            ("recall", Json::Num(sharded_recall)),
            ("ef", Json::Int(sp.ef as u64)),
            ("batch", Json::Int(n_queries as u64)),
            ("searcher", Json::s("S=4 pool")),
            ("threads", Json::Int(threads as u64)),
        ]));
    }

    // ---- micro-batching front-end (api::front::ServeFront) ----
    // Queries submitted one at a time, coalesced into windows — the
    // serving-edge view of the same pool (per-query results identical
    // to the batched path by construction; here we measure the
    // amortization the window buys over truly individual dispatch).
    let pool = ShardPool::new(&sharded, 4).unwrap();
    let front = ServeFront::spawn(
        pool,
        dim,
        FrontConfig {
            k,
            params: sp,
            max_batch: 256,
            max_wait: std::time::Duration::from_micros(200),
            ..Default::default()
        },
    )
    .unwrap();
    let (front_totals, front_secs) = measure_once(|| {
        let tickets: Vec<_> = (0..n_queries)
            .map(|qi| front.submit(qmat.row_logical(qi).to_vec()).unwrap())
            .collect();
        for ticket in tickets {
            ticket.wait().unwrap();
        }
        front.stats()
    });
    let front_qps = n_queries as f64 / front_secs;
    ttable.row(&[
        "S=4 front".into(),
        "4".into(),
        format!("{front_qps:.0}"),
        format!("{:.2}x", front_qps / one_thread_qps.max(1e-12)),
        format!("{} windows", front_totals.windows),
    ]);
    json_rows.push(Json::obj(vec![
        ("kernel", Json::s(dispatch::active_width().name())),
        ("qps", Json::Num(front_qps)),
        ("ef", Json::Int(sp.ef as u64)),
        ("batch", Json::Int(n_queries as u64)),
        ("searcher", Json::s("S=4 front")),
        ("threads", Json::Int(4)),
        ("windows", Json::Int(front_totals.windows)),
        ("coalesced", Json::Int(front_totals.coalesced)),
    ]));
    drop(front);
    ttable.finish();

    // ---- centroid-routed fan-out (api::partition::KMeans router) ----
    // k-means S=4 shards over the same corpus; each query fans out only
    // to its top-m shards by query-to-centroid distance. m = S must
    // reproduce the full fan-out bit for bit (asserted); m = 2 is the
    // acceptance point: ≥ 1.5× fewer distance evaluations per query at
    // ≤ 0.03 recall cost (also asserted, not just reported).
    let (kshard, kshard_secs) = measure_once(|| {
        ShardedSearcher::build_partitioned(&corpus, 4, &params, &KMeans::new(7)).unwrap()
    });
    println!(
        "k-means sharded searcher built in {kshard_secs:.2}s (sizes {:?})",
        kshard.shard_sizes()
    );
    let mut rtable = Table::new(
        "query_throughput_routed",
        &["fanout", "qps", "evals/query", "visits/query", "recall@10", "eval reduction"],
    );
    let (full_res, full_stats) = kshard.search_batch(&qmat, k, &sp);
    let full_recall = recall_vs_exact(&full_res[..sample], &truth);
    let mut route_rows = Vec::new();
    for top_m in [4usize, 2, 1] {
        let (res, rstats) = kshard.search_batch_routed(&qmat, k, &sp, top_m);
        if top_m == 4 {
            knng::testing::assert_neighbors_bitwise_eq(&full_res, &res, "routed m=S");
            assert_eq!(
                full_stats.dist_evals, rstats.dist_evals,
                "m=S routing must add no distance evaluations"
            );
        }
        let recall = recall_vs_exact(&res[..sample], &truth);
        let reduction = full_stats.dist_evals as f64 / rstats.dist_evals.max(1) as f64;
        if top_m == 2 {
            assert!(
                reduction >= 1.5,
                "m=2 must cut evals ≥1.5×: full {} vs routed {}",
                full_stats.dist_evals,
                rstats.dist_evals
            );
            assert!(
                recall >= full_recall - 0.03,
                "m=2 recall {recall} fell more than 0.03 below full fan-out {full_recall}"
            );
        }
        rtable.row(&[
            format!("{top_m}/4"),
            format!("{:.0}", rstats.qps()),
            format!("{:.0}", rstats.dist_evals_per_query()),
            format!("{:.2}", rstats.shard_visits as f64 / n_queries as f64),
            format!("{recall:.4}"),
            format!("{reduction:.2}x"),
        ]);
        route_rows.push(Json::obj(vec![
            ("fanout", Json::Int(top_m as u64)),
            ("shards", Json::Int(4)),
            ("qps", Json::Num(rstats.qps())),
            ("evals_per_query", Json::Num(rstats.dist_evals_per_query())),
            (
                "shard_visits_per_query",
                Json::Num(rstats.shard_visits as f64 / n_queries as f64),
            ),
            ("recall", Json::Num(recall)),
            ("eval_reduction_vs_full", Json::Num(reduction)),
            ("ef", Json::Int(sp.ef as u64)),
        ]));
    }
    rtable.finish();
    write_bench_json(
        "BENCH_route.json",
        &Json::obj(vec![
            ("bench", Json::s("routed_fanout")),
            ("dataset", Json::s("clustered")),
            ("partitioner", Json::s("kmeans")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(dim as u64)),
            ("k", Json::Int(k as u64)),
            ("queries", Json::Int(n_queries as u64)),
            ("full_fanout_recall", Json::Num(full_recall)),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("rows", Json::Arr(route_rows)),
        ]),
    );

    write_bench_json(
        "BENCH_query.json",
        &Json::obj(vec![
            ("bench", Json::s("query_throughput")),
            ("dataset", Json::s("clustered")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(dim as u64)),
            ("k", Json::Int(k as u64)),
            ("queries", Json::Int(n_queries as u64)),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("rows", Json::Arr(json_rows)),
        ]),
    );
}
