//! Fig 4 — cluster distribution after greedy reordering.
//!
//! Paper: Synthetic Clustered, n=16'384, d=8, 8 clusters; sliding
//! 2000-wide window over the reordered memory layout. Early positions
//! are dominated by single clusters (fractions near 1); the tail decays
//! to the 1/8 mixing line because the single-pass heuristic strands
//! late leftovers.
//!
//! Run: `cargo bench --bench bench_cluster_quality` (CSV via KNNG_BENCH_CSV)

use knng::bench::{full_scale, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::metrics::window::{cluster_window_fractions, mean_max_fraction};
use knng::nndescent::reorder::greedy_permutation;
use knng::nndescent::{NnDescent, Params};
use knng::cachesim::trace::NoTracer;

fn main() {
    let n = if full_scale() { 16_384 } else { 8_192 };
    let clusters = 8;
    let window = n / 8; // paper: 2000 of 16384
    let step = window / 8;
    println!("Fig 4 — cluster recovery, Synthetic Clustered n={n} c={clusters} d=8");

    let (data, labels) = SynthClustered::new(n, 8, clusters, 0xF14).generate_labeled();

    // early approximation: 2 iterations, as the heuristic is meant to run
    let params = Params::default()
        .with_k(20)
        .with_seed(4)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked)
        .with_max_iters(2);
    let result = NnDescent::new(params).build(&data).unwrap();
    let reordering = greedy_permutation(&result.graph, &mut NoTracer);
    reordering.validate().expect("valid permutation");

    // order[p] = original node at position p (= inv)
    let fr_greedy = cluster_window_fractions(&reordering.inv, &labels, clusters, window, step);
    let identity: Vec<u32> = (0..n as u32).collect();
    let fr_orig = cluster_window_fractions(&identity, &labels, clusters, window, step);

    let mut table = Table::new(
        "fig4_cluster_windows",
        &["window_start", "max_fraction_greedy", "max_fraction_original", "greedy_fractions"],
    );
    for ((start, fg), (_, fo)) in fr_greedy.iter().zip(&fr_orig) {
        let maxg = fg.iter().cloned().fold(0.0, f64::max);
        let maxo = fo.iter().cloned().fold(0.0, f64::max);
        table.row(&[
            start.to_string(),
            format!("{maxg:.3}"),
            format!("{maxo:.3}"),
            fg.iter().map(|f| format!("{f:.2}")).collect::<Vec<_>>().join(" "),
        ]);
    }
    table.finish();

    let mg = mean_max_fraction(&fr_greedy);
    let mo = mean_max_fraction(&fr_orig);
    println!("\nmean max-cluster fraction: greedy {mg:.3} vs original {mo:.3} (random ≈ {:.3})", 1.0 / clusters as f64);
    println!("paper reference: clusters recovered contiguously early, ≈1/8 mixed tail");
    assert!(mg > mo, "greedy reordering must improve cluster contiguity");
}
