//! Fig 3 — roofline plot points.
//!
//! Paper: π = 24 flops/cycle, β = 4.77 bytes/cycle (i7-9700K);
//! Synthetic Gaussian n=131'072, d ∈ {8, 256}. Claims: d=8 sits on the
//! memory slope (left of the ridge), d=256 is compute-bound (right of
//! it), and the greedy heuristic moves the d=8 point right by cutting Q.
//!
//! W comes from counted distance evaluations; Q from the simulated LL
//! misses (+ writebacks) × line size; cycles from wall time at the
//! nominal 3.6 GHz clock. Absolute flops/cycle differ from the paper's
//! machine — the claims are about positions relative to the ridge.
//!
//! Run: `cargo bench --bench bench_roofline` (`KNNG_BENCH_FULL=1` = paper n)

use knng::bench::{full_scale, measure_once, write_bench_json, Json, Table};
use knng::cachesim::{CacheTracer, Geometry};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::distance::dispatch;
use knng::distance::KernelWidth;
use knng::nndescent::compute::NativeEngine;
use knng::nndescent::{NnDescent, Params};
use knng::roofline::{ridge_intensity, Machine, RooflinePoint};

fn point(label: &str, n: usize, d: usize, reorder: bool, geom: Geometry, machine: &Machine) -> RooflinePoint {
    let data = SynthGaussian::multi(n, d, 0xF13).generate();
    let params = Params::default()
        .with_k(20)
        .with_seed(3)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked)
        .with_reorder(reorder);
    // Two identical runs (same seed ⇒ same access pattern): the traced
    // one yields Q via the cache simulator, the untraced one yields the
    // *real* wall time and W — tracing overhead must not pollute perf.
    let mut tracer = CacheTracer::new(geom);
    let mut engine = NativeEngine::new(ComputeKind::Blocked);
    let _ = NnDescent::new(params.clone()).build_with_engine(&data, &mut engine, &mut tracer);
    let (result, secs) = measure_once(|| NnDescent::new(params).build(&data).unwrap());
    RooflinePoint::from_counters(
        label,
        &result.stats,
        &tracer.stats(),
        tracer.ll_writebacks(),
        secs,
        machine,
    )
}

fn main() {
    let machine = Machine::default();
    let (n, geom) = if full_scale() {
        (131_072, Geometry::default())
    } else {
        (16_384, Geometry { ll_size: 1 << 20, ..Geometry::default() })
    };
    println!(
        "Fig 3 — roofline, Synthetic Gaussian n={n}; π={} f/c, β={} B/c, ridge I*={:.2} f/B",
        machine.pi,
        machine.beta,
        ridge_intensity(&machine)
    );

    let pts = vec![
        point("no-heuristic d=8", n, 8, false, geom, &machine),
        point("greedyheuristic d=8", n, 8, true, geom, &machine),
        point("no-heuristic d=256", n, 256, false, geom, &machine),
    ];

    let mut table = Table::new(
        "fig3_roofline",
        &["config", "W_flops", "Q_bytes", "intensity", "bound_side", "perf_f_per_c", "roofline_bound", "efficiency"],
    );
    for p in &pts {
        table.row(&[
            p.label.clone(),
            format!("{:.3e}", p.flops),
            format!("{:.3e}", p.bytes),
            format!("{:.3}", p.intensity()),
            if p.memory_bound(&machine) { "memory".into() } else { "compute".into() },
            format!("{:.3}", p.perf(&machine)),
            format!("{:.2}", p.bound(&machine)),
            format!("{:.2}", p.efficiency(&machine)),
        ]);
    }
    table.finish();

    // the three claims of Fig 3, asserted
    let (d8, d8g, d256) = (&pts[0], &pts[1], &pts[2]);
    println!("\nclaims:");
    println!(
        "  d=8 memory-bound: {} | d=256 compute-bound: {} | greedy raises d=8 intensity: {:.3} → {:.3}",
        d8.memory_bound(&machine),
        !d256.memory_bound(&machine),
        d8.intensity(),
        d8g.intensity()
    );
    assert!(d8.intensity() < d256.intensity(), "d=256 must have higher intensity");
    assert!(d8g.intensity() > d8.intensity(), "greedy must raise operational intensity");

    // ---- per-kernel-width rows on the compute-bound shape ------------
    // d=256 is right of the ridge, so kernel width is the lever there;
    // a smaller n keeps the scalar build affordable.
    println!("\nkernel dispatch: {}", dispatch::describe());
    let n_w = if full_scale() { 16_384 } else { 4_096 };
    let d_w = 256;
    let mut wtable = Table::new(
        "roofline_by_kernel",
        &["kernel", "secs", "dist_evals", "gflops/s", "vs w8"],
    );
    // dataset and params do not depend on the forced width — generate
    // once; measure all widths first so every row (including scalar,
    // which runs before w8) gets a "vs w8" ratio
    let data = SynthGaussian::multi(n_w, d_w, 0xF13).generate();
    let mut runs = Vec::new();
    for width in KernelWidth::ALL {
        dispatch::force(Some(width));
        let params = Params::default()
            .with_k(20)
            .with_seed(3)
            .with_selection(SelectionKind::Turbo)
            .with_compute(ComputeKind::Blocked);
        let (result, secs) = measure_once(|| NnDescent::new(params).build(&data).unwrap());
        runs.push((width, secs, result.stats.dist_evals, result.stats.flops()));
    }
    dispatch::force(None);

    let w8_secs = runs
        .iter()
        .find(|(w, ..)| *w == KernelWidth::W8)
        .map(|&(_, secs, ..)| secs)
        .unwrap_or(0.0);
    let mut rows_json = Vec::new();
    for &(width, secs, dist_evals, flops) in &runs {
        let gflops = flops as f64 / secs / 1e9;
        wtable.row(&[
            width.name().into(),
            format!("{secs:.2}"),
            format!("{dist_evals}"),
            format!("{gflops:.2}"),
            if w8_secs > 0.0 { format!("{:.2}x", w8_secs / secs) } else { "-".into() },
        ]);
        rows_json.push(Json::obj(vec![
            ("kernel", Json::s(width.name())),
            ("n", Json::Int(n_w as u64)),
            ("d", Json::Int(d_w as u64)),
            ("secs", Json::Num(secs)),
            ("dist_evals", Json::Int(dist_evals)),
            ("flops", Json::Int(flops)),
            ("gflops_per_sec", Json::Num(gflops)),
        ]));
    }
    wtable.finish();

    // Fig-3 points + per-width rows as the machine-readable artifact
    let fig3_json: Vec<Json> = pts
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("label", Json::s(p.label.clone())),
                ("kernel", Json::s(dispatch::active_width().name())),
                ("n", Json::Int(n as u64)),
                ("flops", Json::Num(p.flops)),
                ("bytes", Json::Num(p.bytes)),
                ("intensity", Json::Num(p.intensity())),
                ("perf_f_per_c", Json::Num(p.perf(&machine))),
                ("memory_bound", Json::Bool(p.memory_bound(&machine))),
            ])
        })
        .collect();
    write_bench_json(
        "BENCH_roofline.json",
        &Json::obj(vec![
            ("bench", Json::s("roofline")),
            ("dataset", Json::s("gaussian-multi")),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("fig3_points", Json::Arr(fig3_json)),
            ("by_kernel", Json::Arr(rows_json)),
        ]),
    );
}
