//! Network serving throughput — the `KNNQv1` loopback stack against the
//! same `ServeFront` driven in-process, at 1 / 4 / 16 concurrent
//! clients submitting one query per request. Reports qps and per-query
//! round-trip p50/p99, so the table answers "what does the wire cost"
//! directly: both modes run the identical micro-batching front over the
//! identical S=4 thread pool, and the only delta is TCP + the frame
//! codec. The bit-identity gate is asserted in-bench (a full query tile
//! over loopback must match direct `search_batch` bit for bit), not
//! just eyeballed.
//!
//! Run: `cargo bench --bench bench_net_throughput`

use knng::api::{FrontConfig, Searcher, ServeFront, ShardPool, ShardedSearcher};
use knng::bench::{full_scale, measure_once, write_bench_json, Json, Table};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::dispatch;
use knng::net::{NetClient, NetServer, ServerConfig};
use knng::nndescent::Params;
use knng::search::SearchParams;
use std::time::{Duration, Instant};

const CONNS: [usize; 3] = [1, 4, 16];

/// Percentile of an ascending-sorted slice (nearest-rank).
fn pctl(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn main() {
    println!("kernel dispatch: {}", dispatch::describe());
    let scale = if full_scale() { 4 } else { 1 };
    let n = 8192 * scale;
    let n_queries = 512 * scale;
    let (dim, k) = (32, 10);
    println!("net throughput — corpus n={n} d={dim}, {n_queries} queries, k={k}, loopback TCP");

    let (all, _) = SynthClustered::new(n + n_queries, dim, 16, 0x4E7).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    let queries_flat: Vec<f32> =
        (n..n + n_queries).flat_map(|i| all.row_logical(i).to_vec()).collect();
    let qmat = AlignedMatrix::from_rows(n_queries, dim, &queries_flat);

    let params = Params::default().with_k(16).with_seed(7).with_reorder(true);
    let (sharded, build_secs) =
        measure_once(|| ShardedSearcher::build(&corpus, 4, &params).unwrap());
    println!("S=4 sharded searcher built in {build_secs:.2}s");
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&qmat, k, &sp);

    let front_cfg = || FrontConfig {
        k,
        params: sp,
        max_batch: 256,
        max_wait: Duration::from_micros(200),
        ..Default::default()
    };

    let mut table =
        Table::new("net_throughput", &["mode", "conns", "qps", "p50 µs", "p99 µs", "vs in-proc"]);
    let mut json_rows = Vec::new();
    let mut in_proc_qps = [0.0f64; CONNS.len()];

    // ---- in-process baseline: same front, same pool, no wire ----
    {
        let pool = ShardPool::new(&sharded, 4).unwrap();
        let front = ServeFront::spawn(pool, dim, front_cfg()).unwrap();
        for (ci, &conns) in CONNS.iter().enumerate() {
            let t0 = Instant::now();
            let mut lats: Vec<f64> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..conns)
                    .map(|t| {
                        let front = &front;
                        let qmat = &qmat;
                        s.spawn(move || {
                            let mut lat = Vec::new();
                            let mut qi = t;
                            while qi < n_queries {
                                let q0 = Instant::now();
                                let ticket = front.submit(qmat.row_logical(qi).to_vec()).unwrap();
                                ticket.wait().unwrap();
                                lat.push(q0.elapsed().as_secs_f64() * 1e6);
                                qi += conns;
                            }
                            lat
                        })
                    })
                    .collect();
                handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
            });
            let secs = t0.elapsed().as_secs_f64();
            lats.sort_by(|a, b| a.total_cmp(b));
            let qps = n_queries as f64 / secs;
            in_proc_qps[ci] = qps;
            let (p50, p99) = (pctl(&lats, 0.50), pctl(&lats, 0.99));
            table.row(&[
                "in-process".into(),
                format!("{conns}"),
                format!("{qps:.0}"),
                format!("{p50:.0}"),
                format!("{p99:.0}"),
                "1.00x".into(),
            ]);
            json_rows.push(Json::obj(vec![
                ("mode", Json::s("in_process")),
                ("conns", Json::Int(conns as u64)),
                ("qps", Json::Num(qps)),
                ("p50_us", Json::Num(p50)),
                ("p99_us", Json::Num(p99)),
            ]));
        }
        front.shutdown();
    }

    // ---- loopback: the same front behind the KNNQv1 server ----
    let pool = ShardPool::new(&sharded, 4).unwrap();
    let front = ServeFront::spawn(pool, dim, front_cfg()).unwrap();
    let server_cfg = ServerConfig { workers: 16, ..Default::default() };
    let handle = NetServer::bind("127.0.0.1:0", front, server_cfg).unwrap().spawn().unwrap();
    let addr = handle.addr();

    // the acceptance gate: a full tile over loopback is bit-identical
    // to direct search_batch (transport adds no computation)
    let mut gate = NetClient::connect(addr).unwrap();
    let (wire_res, _) = gate.query_batch(&qmat, k, None).unwrap();
    knng::testing::assert_neighbors_bitwise_eq(&expect, &wire_res, "loopback vs direct");
    drop(gate);
    println!("bit-identity gate: loopback full-tile answers == direct search_batch");

    for (ci, &conns) in CONNS.iter().enumerate() {
        let t0 = Instant::now();
        let mut lats: Vec<f64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..conns)
                .map(|t| {
                    let qmat = &qmat;
                    s.spawn(move || {
                        let mut client = NetClient::connect(addr).unwrap();
                        let mut lat = Vec::new();
                        let mut qi = t;
                        while qi < n_queries {
                            let tile = AlignedMatrix::from_rows(1, dim, qmat.row_logical(qi));
                            let q0 = Instant::now();
                            client.query_batch(&tile, k, None).unwrap();
                            lat.push(q0.elapsed().as_secs_f64() * 1e6);
                            qi += conns;
                        }
                        lat
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().unwrap()).collect()
        });
        let secs = t0.elapsed().as_secs_f64();
        lats.sort_by(|a, b| a.total_cmp(b));
        let qps = n_queries as f64 / secs;
        let (p50, p99) = (pctl(&lats, 0.50), pctl(&lats, 0.99));
        table.row(&[
            "loopback".into(),
            format!("{conns}"),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{:.2}x", qps / in_proc_qps[ci].max(1e-12)),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::s("loopback")),
            ("conns", Json::Int(conns as u64)),
            ("qps", Json::Num(qps)),
            ("p50_us", Json::Num(p50)),
            ("p99_us", Json::Num(p99)),
            ("vs_in_process", Json::Num(qps / in_proc_qps[ci].max(1e-12))),
        ]));
    }
    table.finish();

    let (net, totals) = handle.stop().unwrap();
    println!(
        "server totals: {} connections, {} frames, {} queries, {} windows, {} coalesced",
        net.connections, net.frames, net.queries, totals.windows, totals.coalesced
    );

    write_bench_json(
        "BENCH_net.json",
        &Json::obj(vec![
            ("bench", Json::s("net_throughput")),
            ("protocol", Json::s("KNNQv1")),
            ("dataset", Json::s("clustered")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(dim as u64)),
            ("k", Json::Int(k as u64)),
            ("queries", Json::Int(n_queries as u64)),
            ("bit_identical_to_in_process", Json::Bool(true)),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("rows", Json::Arr(json_rows)),
        ]),
    );
}
