//! Fault-tolerance cost — what degraded serving does to latency and
//! answer quality. Seven modes over the same S=4 `ShardPool`, one
//! query per request:
//!
//! * **healthy** — all shards answering, no deadline. Asserted in-bench
//!   to be bit-identical to the pre-pool inline fan-out
//!   (`ShardedSearcher::search_batch`), so the fault-tolerance
//!   machinery is provably free of behavior drift on the happy path.
//! * **one dead shard** — worker 0 killed and buried (zero respawn
//!   budget); the pool serves survivors. Asserted equal to an honest
//!   3-shard fan-out; recall is measured against the healthy answers.
//! * **deadline-capped** — healthy pool, but every query carries a
//!   budget derived from the healthy p50, so a tail of batches drops
//!   late shards. Reports the degraded fraction and resulting recall.
//! * **replicated R=2, healthy** — two workers per shard over one
//!   shared `Arc<Shard>`. The replication gate: bit-identical to the
//!   R=1 pool and the inline fan-out.
//! * **straggler R=1** — shard 0's worker stalls before every reply
//!   and there is no replica to hedge to: every query eats the stall.
//!   The latency baseline hedging is measured against.
//! * **hedged straggler R=2** — same stall, but past the hedge delay
//!   the shard re-dispatches to replica 1 and the first reply wins:
//!   p50 collapses from the stall to roughly the hedge delay, still
//!   bit-identical, zero degradation.
//! * **dead primary R=2** — shard 0's primary killed and buried; every
//!   batch fails over to replica 1 in-batch. The failover gate: full
//!   fan-out bits, zero degradation tags.
//!
//! Run: `cargo bench --bench bench_fault_tolerance`

use knng::api::{Neighbor, PoolConfig, Searcher, ShardPool, ShardedSearcher};
use knng::bench::{full_scale, measure_once, write_bench_json, Json, Table};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::dispatch;
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::testing::faults::{self, site, FaultPlan};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Percentile of an ascending-sorted slice (nearest-rank).
fn pctl(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Fraction of `truth`'s ids that `got` kept, averaged over queries.
fn recall_vs(truth: &[Vec<Neighbor>], got: &[Vec<Neighbor>]) -> f64 {
    let mut acc = 0.0;
    for (t, g) in truth.iter().zip(got) {
        if t.is_empty() {
            acc += 1.0;
            continue;
        }
        let hits = t.iter().filter(|n| g.iter().any(|m| m.id == n.id)).count();
        acc += hits as f64 / t.len() as f64;
    }
    acc / truth.len().max(1) as f64
}

/// Drive every query through the pool one tile at a time, recording
/// per-query latency, answers, and how many came back degraded.
fn run_mode(
    pool: &ShardPool,
    qmat: &AlignedMatrix,
    k: usize,
    sp: &SearchParams,
    budget: Option<Duration>,
) -> (Vec<Vec<Neighbor>>, Vec<f64>, usize, f64) {
    let dim = qmat.dim();
    let mut answers = Vec::with_capacity(qmat.n());
    let mut lats = Vec::with_capacity(qmat.n());
    let mut degraded = 0usize;
    let t0 = Instant::now();
    for qi in 0..qmat.n() {
        let tile = Arc::new(AlignedMatrix::from_rows(1, dim, qmat.row_logical(qi)));
        let q0 = Instant::now();
        let deadline = budget.map(|b| Instant::now() + b);
        let (mut res, _, degr) = pool.search_batch_deadline_owned(tile, k, sp, None, deadline);
        lats.push(q0.elapsed().as_secs_f64() * 1e6);
        if degr.is_some() {
            degraded += 1;
        }
        answers.push(res.pop().expect("one tile row, one answer"));
    }
    let qps = qmat.n() as f64 / t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    (answers, lats, degraded, qps)
}

fn main() {
    println!("kernel dispatch: {}", dispatch::describe());
    let scale = if full_scale() { 4 } else { 1 };
    let n = 8192 * scale;
    let n_queries = 512 * scale;
    let (dim, k) = (32, 10);
    println!("fault tolerance — corpus n={n} d={dim}, {n_queries} queries, k={k}, S=4 pool");

    let (all, _) = SynthClustered::new(n + n_queries, dim, 16, 0xFA17).generate_labeled();
    let corpus = {
        let rows: Vec<f32> = (0..n).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(n, dim, &rows)
    };
    let queries_flat: Vec<f32> =
        (n..n + n_queries).flat_map(|i| all.row_logical(i).to_vec()).collect();
    let qmat = AlignedMatrix::from_rows(n_queries, dim, &queries_flat);

    let params = Params::default().with_k(16).with_seed(7).with_reorder(true);
    let (sharded, build_secs) =
        measure_once(|| ShardedSearcher::build(&corpus, 4, &params).unwrap());
    println!("S=4 sharded searcher built in {build_secs:.2}s");
    let sp = SearchParams::default();
    // the pre-pool stack's answers: truth for the bit-identity gate and
    // the recall column
    let (expect, _) = sharded.search_batch(&qmat, k, &sp);

    let mut table = Table::new(
        "fault_tolerance",
        &["mode", "qps", "p50 µs", "p99 µs", "recall", "degraded"],
    );
    let mut json_rows = Vec::new();
    let mut emit = |table: &mut Table,
                    mode: &str,
                    lats: &[f64],
                    qps: f64,
                    recall: f64,
                    degraded: usize| {
        let (p50, p99) = (pctl(lats, 0.50), pctl(lats, 0.99));
        table.row(&[
            mode.into(),
            format!("{qps:.0}"),
            format!("{p50:.0}"),
            format!("{p99:.0}"),
            format!("{recall:.4}"),
            format!("{degraded}/{n_queries}"),
        ]);
        json_rows.push(Json::obj(vec![
            ("mode", Json::s(mode)),
            ("qps", Json::Num(qps)),
            ("p50_us", Json::Num(p50)),
            ("p99_us", Json::Num(p99)),
            ("recall_vs_healthy", Json::Num(recall)),
            ("degraded_queries", Json::Int(degraded as u64)),
        ]));
        p50
    };

    // ---- healthy: the gate + the baseline ----------------------------
    let healthy_p50;
    {
        let pool = ShardPool::new(&sharded, 4).unwrap();
        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        // the acceptance gate: the fault-tolerant pool on the happy path
        // is bit-identical to the pre-PR inline fan-out
        knng::testing::assert_neighbors_bitwise_eq(
            &expect,
            &answers,
            "healthy pool vs inline fan-out",
        );
        assert_eq!(degraded, 0, "a healthy pool must not degrade");
        println!("bit-identity gate: healthy pool answers == inline search_batch");
        healthy_p50 = emit(&mut table, "healthy", &lats, qps, 1.0, degraded);
    }

    // ---- one dead shard: survivors keep serving ----------------------
    {
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 4, respawn_budget: 0, ..Default::default() },
        )
        .unwrap();
        // kill worker 0 on its first job and bury shard 0; two warm-up
        // batches make the burial deterministic before timing starts
        faults::install(FaultPlan::new().die_always(site::WORKER_JOB, 0));
        for _ in 0..2 {
            let tile = Arc::new(AlignedMatrix::from_rows(1, dim, qmat.row_logical(0)));
            let _ = pool.search_batch_deadline_owned(tile, k, &sp, None, None);
        }
        faults::clear();
        let stats = pool.stats();
        assert_eq!(stats.dead_shards(), vec![0], "shard 0 must be buried: {stats:?}");

        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        assert_eq!(degraded, n_queries, "every query must be tagged degraded");
        // degraded answers are the honest survivor fan-out, bit for bit
        let (honest, _) = sharded.search_batch_subset(&qmat, k, &sp, &[1, 2, 3]);
        knng::testing::assert_neighbors_bitwise_eq(
            &honest,
            &answers,
            "dead-shard pool vs honest 3-shard fan-out",
        );
        let recall = recall_vs(&expect, &answers);
        emit(&mut table, "one_dead_shard", &lats, qps, recall, degraded);
    }

    // ---- deadline-capped: healthy pool under a tight budget ----------
    {
        let pool = ShardPool::new(&sharded, 4).unwrap();
        // a budget below the healthy median forces a real miss tail
        // while letting most shards answer; floor keeps it meaningful
        // on very fast machines
        let budget = Duration::from_micros((healthy_p50 * 0.75).max(50.0) as u64);
        println!("deadline budget: {budget:?} (healthy p50 was {healthy_p50:.0} µs)");
        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, Some(budget));
        let recall = recall_vs(&expect, &answers);
        emit(&mut table, "deadline_capped", &lats, qps, recall, degraded);
        let misses = pool.stats().deadline_misses;
        println!("deadline-capped: {degraded}/{n_queries} degraded, {misses} shard misses");
    }

    // ---- replicated R=2, healthy: replication is behavior-drift-free -
    {
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 4, replicas: 2, ..Default::default() },
        )
        .unwrap();
        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        // the replication acceptance gate: R=2 answers are bit-identical
        // to the R=1 pool (== the inline fan-out, by the healthy gate)
        knng::testing::assert_neighbors_bitwise_eq(
            &expect,
            &answers,
            "healthy R=2 pool vs inline fan-out",
        );
        assert_eq!(degraded, 0, "a healthy replicated pool must not degrade");
        println!("bit-identity gate: R=2 answers == R=1 answers == inline search_batch");
        emit(&mut table, "replicated_r2", &lats, qps, 1.0, degraded);
    }

    // both straggler modes stall shard 0's primary by the same amount
    // before every reply; only R differs
    let stall = Duration::from_micros(2_000);
    let hedge_us = 200u64;

    // ---- straggler R=1: no replica to hedge to — eat the stall -------
    {
        let pool = ShardPool::new(&sharded, 4).unwrap();
        faults::install(FaultPlan::new().delay_always(site::WORKER_REPLY, 0, stall));
        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        faults::clear();
        knng::testing::assert_neighbors_bitwise_eq(
            &expect,
            &answers,
            "straggler R=1 vs inline fan-out",
        );
        assert_eq!(degraded, 0, "a slow shard without a deadline must not degrade");
        emit(&mut table, "straggler_r1", &lats, qps, 1.0, degraded);
    }

    // ---- hedged straggler R=2: the hedge caps the stall --------------
    {
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 4, replicas: 2, hedge_us, ..Default::default() },
        )
        .unwrap();
        faults::install(FaultPlan::new().delay_always(site::WORKER_REPLY, 0, stall));
        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        // clear before the pool drops so the stalled primary's job
        // backlog drains undelayed
        faults::clear();
        knng::testing::assert_neighbors_bitwise_eq(
            &expect,
            &answers,
            "hedged straggler R=2 vs inline fan-out",
        );
        assert_eq!(degraded, 0, "a hedged straggler must not degrade");
        let stats = pool.stats();
        assert!(stats.hedges_sent > 0, "the stall must trigger hedges: {stats:?}");
        assert!(stats.hedge_wins > 0, "the replica must win hedges: {stats:?}");
        println!(
            "hedged straggler: {} hedges sent, {} won (hedge delay {hedge_us} µs, stall {stall:?})",
            stats.hedges_sent, stats.hedge_wins
        );
        emit(&mut table, "hedged_straggler_r2", &lats, qps, 1.0, degraded);
    }

    // ---- dead primary R=2: in-batch failover, zero degradation -------
    {
        let pool = ShardPool::with_config(
            &sharded,
            PoolConfig { threads: 4, replicas: 2, respawn_budget: 0, ..Default::default() },
        )
        .unwrap();
        // kill shard 0's primary on its first job; warm-up batches make
        // the burial deterministic before timing starts
        faults::install(FaultPlan::new().die_always(site::WORKER_JOB, 0));
        for _ in 0..2 {
            let tile = Arc::new(AlignedMatrix::from_rows(1, dim, qmat.row_logical(0)));
            let _ = pool.search_batch_deadline_owned(tile, k, &sp, None, None);
        }
        faults::clear();
        let stats = pool.stats();
        assert!(
            stats.dead_shards().is_empty(),
            "replica 1 must keep shard 0 alive: {stats:?}"
        );
        assert_eq!(
            stats.replica_states[0][0],
            knng::api::ShardState::Dead,
            "shard 0's primary must be buried: {stats:?}"
        );

        let (answers, lats, degraded, qps) = run_mode(&pool, &qmat, k, &sp, None);
        // the failover acceptance gate: a dead primary costs zero
        // answers — full fan-out bits, zero degradation tags
        knng::testing::assert_neighbors_bitwise_eq(
            &expect,
            &answers,
            "dead-primary R=2 pool vs inline fan-out",
        );
        assert_eq!(degraded, 0, "failover must replace degradation");
        let stats = pool.stats();
        assert!(
            stats.failovers as usize >= n_queries,
            "every batch must fail over: {stats:?}"
        );
        println!("dead primary: {} failovers, 0 degraded", stats.failovers);
        emit(&mut table, "replica_dead_r2", &lats, qps, 1.0, degraded);
    }
    table.finish();

    write_bench_json(
        "BENCH_fault.json",
        &Json::obj(vec![
            ("bench", Json::s("fault_tolerance")),
            ("dataset", Json::s("clustered")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(dim as u64)),
            ("k", Json::Int(k as u64)),
            ("queries", Json::Int(n_queries as u64)),
            ("shards", Json::Int(4)),
            ("healthy_bit_identical_to_inline", Json::Bool(true)),
            ("r2_bit_identical_to_r1", Json::Bool(true)),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("rows", Json::Arr(json_rows)),
        ]),
    );
}
