//! Fig 6 — performance [flops/cycle] vs dataset size n at d=256.
//!
//! Paper: Synthetic Gaussian, d=256 fixed, n sweeping; one line per
//! cumulative version tag (turbosampling → l2intrinsics → mem-align →
//! blocked → greedyheuristic), ≈1.5× total gain, performance degrading
//! as n outgrows the caches.
//!
//! Tag mapping (see DESIGN.md §1): our `scalar` compute ≙ turbosampling
//! baseline (selection already turbo), `unrolled` ≙ l2intrinsics +
//! mem-align (alignment is structural in AlignedMatrix), `blocked` ≙
//! blocked, `blocked+reorder` ≙ greedyheuristic.
//!
//! Run: `cargo bench --bench bench_scaling_n` (CI sizes)
//!      `KNNG_BENCH_FULL=1 ...` for the paper's n range.

use knng::bench::{full_scale, measure_once, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::nndescent::{NnDescent, Params};
use knng::util::timer::DEFAULT_NOMINAL_HZ;

fn variants() -> Vec<(&'static str, ComputeKind, bool)> {
    vec![
        ("turbosampling", ComputeKind::Scalar, false),
        ("l2intrinsics+memalign", ComputeKind::Unrolled, false),
        ("blocked", ComputeKind::Blocked, false),
        ("greedyheuristic", ComputeKind::Blocked, true),
    ]
}

fn main() {
    let d = 256;
    let ns: Vec<usize> = if full_scale() {
        vec![2048, 4096, 8192, 16_384, 32_768, 65_536]
    } else {
        vec![1024, 2048, 4096]
    };
    println!("Fig 6 — perf vs n at d={d} (Synthetic Gaussian, k=20)");

    let mut table = Table::new("fig6_scaling_n", &["variant", "n", "secs", "dist_evals", "flops_per_cycle"]);
    for &n in &ns {
        let data = SynthGaussian::multi(n, d, 0xF16).generate();
        for (tag, compute, reorder) in variants() {
            let params = Params::default()
                .with_k(20)
                .with_seed(6)
                .with_selection(SelectionKind::Turbo)
                .with_compute(compute)
                .with_reorder(reorder);
            let (result, secs) =
                measure_once(|| NnDescent::new(params.clone()).build(&data).unwrap());
            let flops = result.stats.flops() as f64;
            let fpc = flops / (secs * DEFAULT_NOMINAL_HZ);
            table.row(&[
                tag.to_string(),
                n.to_string(),
                format!("{secs:.3}"),
                result.stats.dist_evals.to_string(),
                format!("{fpc:.3}"),
            ]);
        }
    }
    table.finish();
    println!(
        "\npaper reference: each tag adds a layer; total ≈1.5× turbosampling→greedyheuristic; \
         perf decays as n outgrows LL cache"
    );
}
