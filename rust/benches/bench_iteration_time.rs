//! Fig 5 — per-iteration time with/without the greedy reordering.
//!
//! Paper: Synthetic Clustered (n=16'384, 16 clusters, d=8). The
//! reordered run pays overhead in the iteration where the heuristic
//! executes, then wins every subsequent iteration; total speedup
//! ≈18.46% over all iterations.
//!
//! Run: `cargo bench --bench bench_iteration_time`

use knng::bench::{full_scale, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::nndescent::{NnDescent, Params};

fn main() {
    let n = if full_scale() { 16_384 } else { 8_192 };
    let (d, clusters, k) = (8, 16, 20);
    println!("Fig 5 — per-iteration time, Synthetic Clustered n={n} c={clusters} d={d} k={k}");

    let (data, _) = SynthClustered::new(n, d, clusters, 0xF15).generate_labeled();
    let base = Params::default()
        .with_k(k)
        .with_seed(5)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked);

    let plain = NnDescent::new(base.clone().with_reorder(false)).build(&data).unwrap();
    let greedy = NnDescent::new(base.with_reorder(true)).build(&data).unwrap();

    let mut table = Table::new(
        "fig5_iteration_time",
        &["iter", "no_heuristic_secs", "greedy_secs", "greedy_reorder_overhead"],
    );
    let iters = plain.per_iter.len().max(greedy.per_iter.len());
    for i in 0..iters {
        let p = plain.per_iter.get(i);
        let g = greedy.per_iter.get(i);
        table.row(&[
            i.to_string(),
            p.map(|s| format!("{:.4}", s.total_secs())).unwrap_or_else(|| "-".into()),
            g.map(|s| format!("{:.4}", s.total_secs())).unwrap_or_else(|| "-".into()),
            g.map(|s| format!("{:.4}", s.reorder_secs)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.finish();

    let tp: f64 = plain.per_iter.iter().map(|s| s.total_secs()).sum();
    let tg: f64 = greedy.per_iter.iter().map(|s| s.total_secs()).sum();
    println!("\ntotal: no-heuristic {tp:.3}s, greedy {tg:.3}s → speedup {:.2}%", (tp / tg - 1.0) * 100.0);
    println!("paper reference: 18.46% total speedup; first post-reorder iteration slower");
}
