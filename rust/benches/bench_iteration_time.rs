//! Fig 5 — per-iteration time with/without the greedy reordering, plus
//! the parallel-build scaling rows (threads ∈ {1, 2, 4}).
//!
//! Paper: Synthetic Clustered (n=16'384, 16 clusters, d=8). The
//! reordered run pays overhead in the iteration where the heuristic
//! executes, then wins every subsequent iteration; total speedup
//! ≈18.46% over all iterations.
//!
//! The threaded section measures the same build at T ∈ {1, 2, 4} and
//! writes `BENCH_build.json` so the build-perf trajectory is tracked
//! across PRs. It also re-asserts the parity contract every run: the
//! T=1 knob must be bit-identical to the plain sequential build.
//!
//! Run: `cargo bench --bench bench_iteration_time`

use knng::bench::{full_scale, measure_once, write_bench_json, Json, Table};
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::clustered::SynthClustered;
use knng::nndescent::{BuildResult, NnDescent, Params};

fn main() {
    let n = if full_scale() { 16_384 } else { 8_192 };
    let (d, clusters, k) = (8, 16, 20);
    println!("Fig 5 — per-iteration time, Synthetic Clustered n={n} c={clusters} d={d} k={k}");

    let (data, _) = SynthClustered::new(n, d, clusters, 0xF15).generate_labeled();
    let base = Params::default()
        .with_k(k)
        .with_seed(5)
        .with_selection(SelectionKind::Turbo)
        .with_compute(ComputeKind::Blocked);

    // fig5 reproduces the *paper's sequential* per-iteration profile:
    // pin T=1 so a PALLAS_BUILD_THREADS environment cannot silently
    // swap the measurement onto the parallel engine (the threaded
    // section below owns that comparison)
    let fig5 = base.clone().with_threads(1);
    let plain = NnDescent::new(fig5.clone().with_reorder(false)).build(&data).unwrap();
    let greedy = NnDescent::new(fig5.with_reorder(true)).build(&data).unwrap();

    let mut table = Table::new(
        "fig5_iteration_time",
        &["iter", "no_heuristic_secs", "greedy_secs", "greedy_reorder_overhead"],
    );
    let iters = plain.per_iter.len().max(greedy.per_iter.len());
    for i in 0..iters {
        let p = plain.per_iter.get(i);
        let g = greedy.per_iter.get(i);
        table.row(&[
            i.to_string(),
            p.map(|s| format!("{:.4}", s.total_secs())).unwrap_or_else(|| "-".into()),
            g.map(|s| format!("{:.4}", s.total_secs())).unwrap_or_else(|| "-".into()),
            g.map(|s| format!("{:.4}", s.reorder_secs)).unwrap_or_else(|| "-".into()),
        ]);
    }
    table.finish();

    let tp: f64 = plain.per_iter.iter().map(|s| s.total_secs()).sum();
    let tg: f64 = greedy.per_iter.iter().map(|s| s.total_secs()).sum();
    println!("\ntotal: no-heuristic {tp:.3}s, greedy {tg:.3}s → speedup {:.2}%", (tp / tg - 1.0) * 100.0);
    println!("paper reference: 18.46% total speedup; first post-reorder iteration slower");

    threaded_build_section(&data, &base, n, d, k);
}

/// Parity gate run on every bench invocation: `--threads 1` must be
/// bit-identical to the plain sequential build (graph, counters,
/// per-iteration stats) — the hard requirement of the parallel engine.
fn assert_t1_parity(seq: &BuildResult, t1: &BuildResult) {
    assert_eq!(seq.iterations, t1.iterations, "T=1 parity: iterations");
    assert_eq!(seq.stats.dist_evals, t1.stats.dist_evals, "T=1 parity: dist evals");
    assert_eq!(seq.total_updates(), t1.total_updates(), "T=1 parity: updates");
    for u in 0..seq.graph.n() {
        assert_eq!(seq.graph.sorted(u), t1.graph.sorted(u), "T=1 parity: node {u}");
    }
    println!("T=1 parity assert passed (bit-identical to the sequential build)");
}

/// Build-time scaling over worker threads; emits `BENCH_build.json`.
fn threaded_build_section(
    data: &knng::dataset::AlignedMatrix,
    base: &Params,
    n: usize,
    d: usize,
    k: usize,
) {
    // reference build through the explicit-engine funnel, which is
    // *always* the sequential code path (immune to PALLAS_BUILD_THREADS)
    let mut engine = knng::nndescent::compute::NativeEngine::new(base.compute);
    let seq = NnDescent::new(base.clone()).build_with_engine(
        data,
        &mut engine,
        &mut knng::cachesim::trace::NoTracer,
    );
    let mut table = Table::new(
        "parallel_build_scaling",
        &["threads", "wall_secs", "iterations", "dist_evals", "updates", "speedup_vs_t1"],
    );
    let mut rows: Vec<Json> = Vec::new();
    let mut t1_secs = f64::NAN;
    for threads in [1usize, 2, 4] {
        let params = base.clone().with_threads(threads);
        let (result, secs) = measure_once(|| NnDescent::new(params.clone()).build(data).unwrap());
        if threads == 1 {
            assert_t1_parity(&seq, &result);
            t1_secs = secs;
        }
        table.row(&[
            threads.to_string(),
            format!("{secs:.4}"),
            result.iterations.to_string(),
            result.stats.dist_evals.to_string(),
            result.total_updates().to_string(),
            format!("{:.2}x", t1_secs / secs),
        ]);
        rows.push(Json::obj(vec![
            ("threads", Json::Int(threads as u64)),
            ("wall_secs", Json::Num(secs)),
            ("build_total_secs", Json::Num(result.total_secs)),
            ("iterations", Json::Int(result.iterations as u64)),
            ("dist_evals", Json::Int(result.stats.dist_evals)),
            ("updates", Json::Int(result.total_updates())),
            ("speedup_vs_t1", Json::Num(t1_secs / secs)),
        ]));
    }
    table.finish();
    write_bench_json(
        "BENCH_build.json",
        &Json::obj(vec![
            ("bench", Json::s("build")),
            ("dataset", Json::s("clustered")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(d as u64)),
            ("k", Json::Int(k as u64)),
            ("kernel", Json::s(knng::distance::dispatch::active_width().name())),
            ("rows", Json::Arr(rows)),
        ]),
    );
}
