//! Storage-engine costs — what the `KNNIv2` zero-copy layer buys and
//! what the mutable path costs on one core:
//!
//! * **open time**, mmap vs heap-copy, over the same segment bytes
//!   (the zero-copy claim in milliseconds), with the bitwise-identity
//!   gate between the two modes asserted in-bench;
//! * **insert throughput** through the WAL + delta path;
//! * **compaction time** for a delta fold with bounded NN-Descent
//!   repair, and the fraction of a cold full build it costs;
//! * **query throughput** before the mutations, with the delta
//!   attached, and after compaction — with the post-compaction
//!   fresh-open parity gate asserted in-bench.
//!
//! Run: `cargo bench --bench bench_store`

use knng::api::IndexBuilder;
use knng::bench::{fmt_secs, full_scale, measure, measure_once, write_bench_json, Json, Table};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::distance::dispatch;
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::store::{MutableIndex, StoreConfig, StoreMode};
use knng::testing::assert_neighbors_bitwise_eq;
use std::time::Instant;

fn main() {
    println!("kernel dispatch: {}", dispatch::describe());
    let scale = if full_scale() { 4 } else { 1 };
    let n = 8192 * scale;
    let n_queries = 256 * scale;
    let n_inserts = n / 8;
    let n_deletes = n / 32;
    let (dim, k) = (32, 10);
    println!(
        "store engine — corpus n={n} d={dim}, {n_queries} queries, k={k}, \
         {n_inserts} inserts + {n_deletes} deletes before compaction"
    );

    let (all, _) = SynthClustered::new(n + n_queries + n_inserts, dim, 16, 0x57E).generate_labeled();
    let take = |from: usize, count: usize| -> AlignedMatrix {
        let rows: Vec<f32> =
            (from..from + count).flat_map(|i| all.row_logical(i).to_vec()).collect();
        AlignedMatrix::from_rows(count, dim, &rows)
    };
    let corpus = take(0, n);
    let qmat = take(n, n_queries);
    let extra = take(n + n_queries, n_inserts);

    let params = Params::default().with_k(16).with_seed(7).with_reorder(true);
    let (index, build_secs) =
        measure_once(|| IndexBuilder::new().data(corpus).params(params).build().unwrap());
    println!("index built in {build_secs:.2}s");

    let dir = std::env::temp_dir().join("knng_bench_store");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let seg_path = dir.join("bench.knni2");
    index.save_segment(&seg_path).unwrap();
    let seg_bytes = std::fs::metadata(&seg_path).unwrap().len();
    println!("segment: {seg_bytes} bytes on disk");
    drop(index);

    let cfg = |mode: Option<StoreMode>| StoreConfig {
        mode,
        auto_compact_ratio: 0.0, // the bench controls the fold
        ..Default::default()
    };
    let sp = SearchParams::default();
    let mut table = Table::new("store", &["step", "value", "detail"]);
    let mut json = Vec::new();

    // ---- open time: mmap vs heap copy, same bytes ----
    let reps = 9;
    let mut open_ms = [0.0f64; 2];
    for (i, mode) in [StoreMode::Mmap, StoreMode::Copy].into_iter().enumerate() {
        let mut samples =
            measure(reps, || MutableIndex::open_with(&seg_path, cfg(Some(mode))).unwrap());
        samples.sort_by(|a, b| a.total_cmp(b));
        open_ms[i] = samples[reps / 2] * 1e3;
        table.row(&[
            format!("open ({})", mode.name()),
            format!("{:.3} ms", open_ms[i]),
            format!("median of {reps}"),
        ]);
        json.push(Json::obj(vec![
            ("step", Json::s(format!("open_{}", mode.name()))),
            ("ms", Json::Num(open_ms[i])),
        ]));
    }
    println!(
        "zero-copy open: mmap {:.3} ms vs heap copy {:.3} ms ({:.1}x)",
        open_ms[0],
        open_ms[1],
        open_ms[1] / open_ms[0].max(1e-9)
    );

    // the mode-interchangeability gate, asserted on full answers
    let mmap_store = MutableIndex::open_with(&seg_path, cfg(Some(StoreMode::Mmap))).unwrap();
    let copy_store = MutableIndex::open_with(&seg_path, cfg(Some(StoreMode::Copy))).unwrap();
    let (expect, _) = mmap_store.search_batch(&qmat, k, &sp);
    let (copy_res, _) = copy_store.search_batch(&qmat, k, &sp);
    assert_neighbors_bitwise_eq(&expect, &copy_res, "mmap vs heap-copy");
    println!("bit-identity gate: mmap answers == heap-copy answers");
    drop(copy_store);

    // ---- baseline query throughput (clean base, no delta) ----
    let qps_base = {
        let t0 = Instant::now();
        let (res, _) = mmap_store.search_batch(&qmat, k, &sp);
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(res.len(), n_queries);
        n_queries as f64 / secs
    };
    drop(mmap_store);

    // ---- insert throughput through WAL + delta ----
    let mut store = MutableIndex::open_with(&seg_path, cfg(None)).unwrap();
    let t0 = Instant::now();
    for i in 0..n_inserts {
        store.insert((n + i) as u32, extra.row_logical(i)).unwrap();
    }
    let insert_secs = t0.elapsed().as_secs_f64();
    let inserts_per_sec = n_inserts as f64 / insert_secs;
    for id in 0..n_deletes as u32 {
        store.delete(id).unwrap();
    }
    table.row(&[
        "insert".into(),
        format!("{inserts_per_sec:.0}/s"),
        format!("{n_inserts} rows, WAL {} B", store.wal_bytes()),
    ]);
    json.push(Json::obj(vec![
        ("step", Json::s("insert")),
        ("rows_per_sec", Json::Num(inserts_per_sec)),
        ("rows", Json::Int(n_inserts as u64)),
    ]));

    // ---- query throughput with the delta attached ----
    let qps_delta = {
        let t0 = Instant::now();
        let (res, _) = store.search_batch(&qmat, k, &sp);
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(res.len(), n_queries);
        n_queries as f64 / secs
    };

    // ---- compaction: bounded repair fold ----
    let (stats, compact_secs) = measure_once(|| store.compact().unwrap());
    table.row(&[
        "compact".into(),
        fmt_secs(compact_secs),
        format!(
            "{} rows (+{} −{}), {} repair iters, {:.1}% of build",
            stats.rows,
            stats.folded,
            stats.dropped,
            stats.repair.iterations,
            100.0 * compact_secs / build_secs.max(1e-9)
        ),
    ]);
    json.push(Json::obj(vec![
        ("step", Json::s("compact")),
        ("secs", Json::Num(compact_secs)),
        ("rows", Json::Int(stats.rows as u64)),
        ("folded", Json::Int(stats.folded as u64)),
        ("dropped", Json::Int(stats.dropped as u64)),
        ("repair_iters", Json::Int(stats.repair.iterations as u64)),
        ("vs_full_build", Json::Num(compact_secs / build_secs.max(1e-9))),
    ]));

    // ---- post-compaction qps + the fresh-open parity gate ----
    let (post, _) = store.search_batch(&qmat, k, &sp);
    let qps_post = {
        let t0 = Instant::now();
        let (res, _) = store.search_batch(&qmat, k, &sp);
        let secs = t0.elapsed().as_secs_f64();
        assert_eq!(res.len(), n_queries);
        n_queries as f64 / secs
    };
    let fresh = MutableIndex::open_with(&seg_path, cfg(None)).unwrap();
    let (fresh_res, _) = fresh.search_batch(&qmat, k, &sp);
    assert_neighbors_bitwise_eq(&post, &fresh_res, "post-compact vs fresh open");
    println!("parity gate: post-compaction answers == fresh open of the compacted segment");

    for (label, qps) in
        [("query (clean base)", qps_base), ("query (with delta)", qps_delta), ("query (compacted)", qps_post)]
    {
        table.row(&[label.into(), format!("{qps:.0} q/s"), String::new()]);
    }
    json.push(Json::obj(vec![
        ("step", Json::s("query")),
        ("qps_clean_base", Json::Num(qps_base)),
        ("qps_with_delta", Json::Num(qps_delta)),
        ("qps_post_compaction", Json::Num(qps_post)),
    ]));
    table.finish();

    write_bench_json(
        "BENCH_store.json",
        &Json::obj(vec![
            ("bench", Json::s("store")),
            ("format", Json::s("KNNIv2")),
            ("dataset", Json::s("clustered")),
            ("n", Json::Int(n as u64)),
            ("dim", Json::Int(dim as u64)),
            ("k", Json::Int(k as u64)),
            ("queries", Json::Int(n_queries as u64)),
            ("segment_bytes", Json::Int(seg_bytes)),
            ("open_mmap_ms", Json::Num(open_ms[0])),
            ("open_copy_ms", Json::Num(open_ms[1])),
            ("modes_bit_identical", Json::Bool(true)),
            ("post_compaction_fresh_open_bit_identical", Json::Bool(true)),
            ("detected_kernel", Json::s(dispatch::detect().name())),
            ("rows", Json::Arr(json)),
        ]),
    );
    let _ = std::fs::remove_dir_all(&dir);
}
