//! Ablation — the runtime-quality trade-off knobs the paper mentions
//! but does not plot ("Multiple parameters could if desired be altered
//! to change the runtime-quality trade-off", §2): ρ (sample rate) and
//! δ (convergence threshold) against recall, runtime, and evaluations.
//!
//! Run: `cargo bench --bench bench_param_sweep`

use knng::baseline::brute::brute_force_knn_sampled;
use knng::bench::{full_scale, measure_once, Table};
use knng::dataset::clustered::SynthClustered;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};

fn main() {
    let n = if full_scale() { 16_384 } else { 6_000 };
    let k = 20;
    println!("ρ/δ runtime-quality sweep, Synthetic Clustered n={n} d=16 c=16, k={k}");
    let (data, _) = SynthClustered::new(n, 16, 16, 0x5EE9).generate_labeled();
    let truth = brute_force_knn_sampled(&data, k, 300, 3);

    let mut table = Table::new(
        "param_sweep",
        &["rho", "delta", "secs", "iters", "dist_evals", "recall"],
    );
    for &rho in &[0.25, 0.5, 1.0] {
        for &delta in &[0.01, 0.001, 0.0001] {
            let params = Params::default().with_k(k).with_seed(8).with_rho(rho).with_delta(delta);
            let (result, secs) =
                measure_once(|| NnDescent::new(params.clone()).build(&data).unwrap());
            let recall = recall_against_truth(&result, &truth);
            table.row(&[
                format!("{rho}"),
                format!("{delta}"),
                format!("{secs:.3}"),
                result.iterations.to_string(),
                result.stats.dist_evals.to_string(),
                format!("{recall:.4}"),
            ]);
        }
    }
    table.finish();
    println!("\nexpected: recall and cost both rise with ρ and with tighter δ (monotone trade-off)");
}
