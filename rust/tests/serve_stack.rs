//! Serve-stack integration: the thread-per-shard pool's bit-equality
//! with the single-threaded fan-out (the acceptance matrix S ∈ {1, 4} ×
//! threads ∈ {1, 4}), the micro-batching front-end's transparency
//! (window composition and duplicate coalescing never change answers),
//! and the `Index` → single-shard bridge the CLI serve path uses.

use knng::api::{
    FrontConfig, IndexBuilder, KMeans, Searcher, ServeFront, ShardPool, ShardedSearcher,
};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::testing::assert_neighbors_bitwise_eq;
use std::sync::Arc;
use std::time::Duration;

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

#[test]
fn pool_is_bit_identical_to_inline_fanout_for_the_acceptance_matrix() {
    // the acceptance criterion: threaded search_batch ==
    // single-threaded ShardedSearcher fan-out, bit for bit, for
    // S ∈ {1, 4} and threads ∈ {1, 4}
    let (all, _) = SynthClustered::new(1000, 16, 8, 41).generate_labeled();
    let corpus = slice_rows(&all, 0, 900);
    let queries = slice_rows(&all, 900, 100);
    let params = Params::default().with_k(12).with_seed(41).with_reorder(true);
    let k = 8;

    for shards in [1usize, 4] {
        let sharded = ShardedSearcher::build(&corpus, shards, &params).unwrap();
        for sp in [
            SearchParams::default(),
            SearchParams { ef: 16, ..Default::default() },
            SearchParams { ef: 128, seeds: 4, ..Default::default() },
        ] {
            let (expect, estats) = sharded.search_batch(&queries, k, &sp);
            for threads in [1usize, 4] {
                let pool = ShardPool::new(&sharded, threads).unwrap();
                assert_eq!(pool.threads(), threads.min(shards));
                let (got, gstats) = pool.search_batch(&queries, k, &sp);
                let ctx = format!("S={shards} threads={threads} ef={}", sp.ef);
                assert_neighbors_bitwise_eq(&expect, &got, &ctx);
                assert_eq!(estats.dist_evals, gstats.dist_evals, "{ctx}: aggregate evals");
                assert_eq!(estats.expansions, gstats.expansions, "{ctx}: aggregate expansions");

                // single-query path matches too (same kernels, 1-row tile)
                for qi in (0..queries.n()).step_by(29) {
                    let (a, sa) = sharded.search(queries.row_logical(qi), k, &sp);
                    let (b, sb) = pool.search(queries.row_logical(qi), k, &sp);
                    assert_neighbors_bitwise_eq(
                        std::slice::from_ref(&a),
                        std::slice::from_ref(&b),
                        &format!("{ctx} single query {qi}"),
                    );
                    assert_eq!(sa, sb, "{ctx} single query {qi} stats");
                }
            }
        }
    }
}

#[test]
fn pool_serves_concurrent_callers_deterministically() {
    // several OS threads hammer one pool with the same batch: every
    // caller must get the bit-identical reference answer (workers
    // interleave jobs from different callers; per-worker scratch and
    // slot-keyed merging keep them independent)
    let (all, _) = SynthClustered::new(700, 8, 4, 47).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = Arc::new(slice_rows(&all, 600, 100));
    let params = Params::default().with_k(10).with_seed(47);
    let sharded = ShardedSearcher::build(&corpus, 4, &params).unwrap();
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, 5, &sp);
    let pool = Arc::new(ShardPool::new(&sharded, 4).unwrap());

    std::thread::scope(|scope| {
        for caller in 0..4 {
            let pool = Arc::clone(&pool);
            let queries = Arc::clone(&queries);
            let expect = &expect;
            scope.spawn(move || {
                for round in 0..3 {
                    let (got, _) = pool.search_batch(&queries, 5, &sp);
                    let ctx = format!("caller {caller} round {round}");
                    assert_neighbors_bitwise_eq(expect, &got, &ctx);
                }
            });
        }
    });
}

#[test]
fn from_index_single_shard_serves_like_the_index() {
    // the CLI serve path's bridge: Index → 1-shard searcher → pool,
    // all three bit-identical (reordered build, so σ mapping is live)
    let (all, _) = SynthClustered::new(600, 8, 4, 53).generate_labeled();
    let corpus = slice_rows(&all, 0, 500);
    let queries = slice_rows(&all, 500, 80);
    let params = Params::default().with_k(10).with_seed(53).with_reorder(true);
    let index = IndexBuilder::new()
        .data_named(corpus.clone(), "clustered")
        .params(params.clone())
        .build()
        .unwrap();
    let sp = SearchParams::default();
    let (expect, estats) = index.search_batch(&queries, 6, &sp);

    let sharded = ShardedSearcher::from_index(index);
    assert_eq!(sharded.shard_count(), 1);
    assert_eq!(Searcher::len(&sharded), 500);
    let (via_shard, sstats) = sharded.search_batch(&queries, 6, &sp);
    assert_neighbors_bitwise_eq(&expect, &via_shard, "from_index");
    assert_eq!(estats.dist_evals, sstats.dist_evals);

    let pool = ShardPool::new(&sharded, 4).unwrap();
    assert_eq!(pool.threads(), 1, "threads clamp to the single shard");
    let (via_pool, pstats) = pool.search_batch(&queries, 6, &sp);
    assert_neighbors_bitwise_eq(&expect, &via_pool, "from_index pool");
    assert_eq!(estats.dist_evals, pstats.dist_evals);
}

#[test]
fn front_answers_match_direct_batch_regardless_of_window_composition() {
    // micro-batching transparency: whatever windows form (and however
    // duplicates coalesce), every caller's answer equals the direct
    // search_batch result for its query
    let (all, _) = SynthClustered::new(700, 8, 4, 59).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = slice_rows(&all, 600, 60);
    let params = Params::default().with_k(10).with_seed(59);
    let k = 5;
    let sp = SearchParams::default();

    let sharded = ShardedSearcher::build(&corpus, 4, &params).unwrap();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 4).unwrap();
    let front = ServeFront::spawn(
        pool,
        corpus.dim(),
        FrontConfig {
            k,
            params: sp,
            max_batch: 16,
            max_wait: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .unwrap();

    // 4 submitter threads × 30 queries each, with every query submitted
    // twice overall (dup pressure) — window composition is nondeterministic
    // by construction, answers must not be
    std::thread::scope(|scope| {
        for t in 0..4 {
            let front = &front;
            let queries = &queries;
            let expect = &expect;
            scope.spawn(move || {
                for i in 0..30 {
                    let qi = (t * 15 + i) % 60; // overlapping ranges → duplicates
                    let ticket = front.submit(queries.row_logical(qi).to_vec()).unwrap();
                    let served = ticket.wait().unwrap();
                    assert!(served.window.requests >= 1);
                    assert!(served.window.unique >= 1);
                    assert!(served.window.unique <= served.window.requests);
                    assert_neighbors_bitwise_eq(
                        std::slice::from_ref(&expect[qi]),
                        std::slice::from_ref(&served.neighbors),
                        &format!("submitter {t} query {qi}"),
                    );
                }
            });
        }
    });

    let totals = front.shutdown();
    assert_eq!(totals.queries, 120, "every submission answered");
    assert!(totals.windows >= 1);
    assert!(totals.coalesced <= totals.queries);
}

#[test]
fn front_coalesces_a_burst_of_identical_queries() {
    // one searcher execution may answer many identical submissions;
    // robust assertions only (window formation is timing-dependent):
    // all answers identical and bit-equal to the direct result, totals
    // consistent
    let (all, _) = SynthClustered::new(400, 8, 4, 61).generate_labeled();
    let corpus = slice_rows(&all, 0, 350);
    let params = Params::default().with_k(8).with_seed(61);
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 2, &params).unwrap();
    let (expect, _) = sharded.search(all.row_logical(380), 4, &sp);
    let pool = ShardPool::new(&sharded, 2).unwrap();
    let front = ServeFront::spawn(
        pool,
        corpus.dim(),
        FrontConfig {
            k: 4,
            params: sp,
            max_batch: 32,
            max_wait: Duration::from_millis(50),
            ..Default::default()
        },
    )
    .unwrap();

    let q = all.row_logical(380).to_vec();
    let tickets: Vec<_> = (0..20).map(|_| front.submit(q.clone()).unwrap()).collect();
    for ticket in tickets {
        let served = ticket.wait().unwrap();
        assert_neighbors_bitwise_eq(
            std::slice::from_ref(&expect),
            std::slice::from_ref(&served.neighbors),
            "identical burst",
        );
        // any window holding more than one of these requests must have
        // deduplicated down to a single unique query
        assert_eq!(served.window.unique, 1, "identical queries never multiply uniques");
        assert_eq!(served.window.coalesced, served.window.requests > 1);
    }
    let totals = front.shutdown();
    assert_eq!(totals.queries, 20);
    // executions = queries − coalesced = number of windows (1 unique each)
    assert_eq!(totals.queries - totals.coalesced, totals.windows);
}

#[test]
fn routed_full_fanout_matches_plain_fanout_across_the_stack() {
    // m = S routing skips centroid scoring entirely, so inline, pool,
    // and front answers (and eval counts) must be bit-identical to the
    // plain full fan-out for S ∈ {1, 4} — the acceptance criterion for
    // the routed serving path
    let (all, _) = SynthClustered::new(800, 8, 4, 71).generate_labeled();
    let corpus = slice_rows(&all, 0, 700);
    let queries = slice_rows(&all, 700, 60);
    let params = Params::default().with_k(10).with_seed(71);
    let k = 6;
    let sp = SearchParams::default();

    for shards in [1usize, 4] {
        let sharded =
            ShardedSearcher::build_partitioned(&corpus, shards, &params, &KMeans::new(71))
                .unwrap();
        let (expect, estats) = sharded.search_batch(&queries, k, &sp);

        let (inline_routed, rstats) = sharded.search_batch_routed(&queries, k, &sp, shards);
        assert_neighbors_bitwise_eq(&expect, &inline_routed, &format!("S={shards} inline"));
        assert_eq!(estats.dist_evals, rstats.dist_evals, "S={shards}: m=S adds no route evals");
        assert_eq!(rstats.shard_visits, (queries.n() * shards) as u64);

        let pool = ShardPool::new(&sharded, 2).unwrap();
        let (via_pool, pstats) = pool.search_batch_routed(&queries, k, &sp, shards);
        assert_neighbors_bitwise_eq(&expect, &via_pool, &format!("S={shards} pool"));
        assert_eq!(estats.dist_evals, pstats.dist_evals, "S={shards}: pool evals");

        let front = ServeFront::spawn(
            ShardPool::new(&sharded, 2).unwrap(),
            corpus.dim(),
            FrontConfig {
                k,
                params: sp,
                max_batch: 16,
                max_wait: Duration::from_millis(2),
                route_top_m: Some(shards),
                ..Default::default()
            },
        )
        .unwrap();
        let tickets: Vec<_> = (0..queries.n())
            .map(|qi| front.submit(queries.row_logical(qi).to_vec()).unwrap())
            .collect();
        for (qi, ticket) in tickets.into_iter().enumerate() {
            let served = ticket.wait().unwrap();
            assert_neighbors_bitwise_eq(
                std::slice::from_ref(&expect[qi]),
                std::slice::from_ref(&served.neighbors),
                &format!("S={shards} front query {qi}"),
            );
        }
        let totals = front.shutdown();
        assert_eq!(totals.queries, queries.n() as u64);
        assert_eq!(
            totals.shard_visits,
            (totals.queries - totals.coalesced) * shards as u64,
            "full fan-out visits every shard per executed query"
        );
    }
}

#[test]
fn front_routing_reduces_fanout_and_matches_inline_routing() {
    // m < S: the front's routed path answers exactly like the inline
    // routed batch — window composition never changes a query's route
    // or result — while visiting only m shards per executed query
    let (all, _) = SynthClustered::new(900, 8, 4, 73).generate_labeled();
    let corpus = slice_rows(&all, 0, 800);
    let queries = slice_rows(&all, 800, 50);
    let params = Params::default().with_k(10).with_seed(73);
    let k = 6;
    let sp = SearchParams::default();
    let top_m = 2;

    let sharded =
        ShardedSearcher::build_partitioned(&corpus, 4, &params, &KMeans::new(73)).unwrap();
    let (expect, rstats) = sharded.search_batch_routed(&queries, k, &sp, top_m);
    let (_, full_stats) = sharded.search_batch(&queries, k, &sp);
    assert!(
        rstats.dist_evals < full_stats.dist_evals,
        "routing must cut distance work: {} vs {}",
        rstats.dist_evals,
        full_stats.dist_evals
    );

    let front = ServeFront::spawn(
        ShardPool::new(&sharded, 3).unwrap(),
        corpus.dim(),
        FrontConfig {
            k,
            params: sp,
            max_batch: 8,
            max_wait: Duration::from_millis(2),
            route_top_m: Some(top_m),
            ..Default::default()
        },
    )
    .unwrap();
    let tickets: Vec<_> = (0..queries.n())
        .map(|qi| front.submit(queries.row_logical(qi).to_vec()).unwrap())
        .collect();
    for (qi, ticket) in tickets.into_iter().enumerate() {
        let served = ticket.wait().unwrap();
        assert_neighbors_bitwise_eq(
            std::slice::from_ref(&expect[qi]),
            std::slice::from_ref(&served.neighbors),
            &format!("front routed query {qi}"),
        );
    }
    let totals = front.shutdown();
    assert_eq!(totals.queries, queries.n() as u64);
    assert_eq!(
        totals.shard_visits,
        (totals.queries - totals.coalesced) * top_m as u64,
        "routed serving visits exactly m shards per executed query"
    );
}

#[test]
fn saved_shard_bundles_reassemble_and_route_identically() {
    // the multi-bundle CLI workflow in-process: build contiguous shards
    // → save_shards → load each bundle → from_indexes → identical
    // answers (plain and routed) to the searcher that wrote them
    let dir = std::env::temp_dir().join("knng_serve_multibundle");
    std::fs::create_dir_all(&dir).unwrap();
    let (all, _) = SynthClustered::new(700, 8, 4, 79).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = slice_rows(&all, 600, 40);
    let params = Params::default().with_k(10).with_seed(79).with_reorder(true);
    let k = 5;
    let sp = SearchParams::default();

    let built = ShardedSearcher::build(&corpus, 3, &params).unwrap();
    let paths = built.save_shards(&dir.join("corpus.knni")).unwrap();
    assert_eq!(paths.len(), 3);

    let indexes: Vec<_> =
        paths.iter().map(|p| knng::api::Index::load(p).unwrap()).collect();
    let reloaded = ShardedSearcher::from_indexes(indexes).unwrap();
    assert_eq!(reloaded.shard_count(), 3);

    let (expect, estats) = built.search_batch(&queries, k, &sp);
    let (got, gstats) = reloaded.search_batch(&queries, k, &sp);
    assert_neighbors_bitwise_eq(&expect, &got, "reloaded full fan-out");
    assert_eq!(estats.dist_evals, gstats.dist_evals);

    for top_m in [1usize, 2, 3] {
        let (a, sa) = built.search_batch_routed(&queries, k, &sp, top_m);
        let (b, sb) = reloaded.search_batch_routed(&queries, k, &sp, top_m);
        assert_neighbors_bitwise_eq(&a, &b, &format!("reloaded routed m={top_m}"));
        assert_eq!(sa.dist_evals, sb.dist_evals, "m={top_m}: routing evals preserved");
        assert_eq!(sa.shard_visits, sb.shard_visits, "m={top_m}: identical routes");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn front_rejects_wrong_arity_and_survives_shutdown() {
    let (all, _) = SynthClustered::new(200, 8, 4, 67).generate_labeled();
    let corpus = slice_rows(&all, 0, 180);
    let sharded =
        ShardedSearcher::build(&corpus, 2, &Params::default().with_k(6).with_seed(67)).unwrap();
    let pool = ShardPool::new(&sharded, 2).unwrap();
    let front = ServeFront::spawn(pool, corpus.dim(), FrontConfig::default()).unwrap();
    assert!(front.submit(vec![0.0; 3]).is_err(), "wrong arity must be rejected");
    let ticket = front.submit(all.row_logical(190).to_vec()).unwrap();
    assert_eq!(ticket.wait().unwrap().neighbors.len(), 10.min(180));
    let totals = front.shutdown();
    assert_eq!(totals.queries, 1);
}
