//! Integration tests over the PJRT runtime — require `make artifacts`
//! (skipped with a notice when the artifact directory is missing, so
//! plain `cargo test` still passes in a fresh checkout).

use knng::cachesim::trace::NoTracer;
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::dataset::synth::SynthGaussian;
use knng::distance::blocked::{pairwise_flat, PairwiseBuf};
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};
use knng::runtime::{ArtifactStore, PjrtEngine, TileScanner};

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/manifest.tsv").exists()
}

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
            return;
        }
    };
}

#[test]
fn store_opens_and_lists_shapes() {
    require_artifacts!();
    let store = ArtifactStore::open("artifacts").unwrap();
    assert!(!store.entries().is_empty());
    let shapes = store.pairwise_shapes();
    assert!(shapes.iter().any(|&(b, d)| b == 64 && d == 256), "default shape set");
    // find_pairwise picks the smallest covering batch
    let (b, d) = store.find_pairwise(40, 256).unwrap();
    assert!(b >= 40 && d == 256);
    assert!(store.find_pairwise(40, 12345).is_none(), "unknown dim");
}

#[test]
fn every_manifest_artifact_compiles() {
    require_artifacts!();
    let mut store = ArtifactStore::open("artifacts").unwrap();
    let keys: Vec<_> = store
        .entries()
        .iter()
        .map(|e| knng::runtime::ArtifactKey {
            kind: match e.kind.as_str() {
                "pairwise" => "pairwise",
                "tilescan" => "tilescan",
                other => panic!("unknown kind {other}"),
            },
            dims: e.dims.clone(),
        })
        .collect();
    for key in keys {
        store.executable(&key).unwrap_or_else(|e| panic!("compiling {key:?}: {e:#}"));
    }
    assert_eq!(store.compiled_count(), store.entries().len());
}

#[test]
fn pjrt_pairwise_matches_native_with_padding() {
    require_artifacts!();
    let mut engine = PjrtEngine::open("artifacts").unwrap();
    let data = SynthGaussian::single(200, 192, 9).generate();
    // deliberately not a full batch (m=23 < B=64) and with repeated ids
    let mut ids: Vec<u32> = (0..22).map(|i| (i * 7) % 200).collect();
    ids.push(ids[0]);
    let mut pjrt = PairwiseBuf::with_capacity(64);
    let mut native = PairwiseBuf::with_capacity(64);
    engine.pairwise_checked(&data, &ids, &mut pjrt).unwrap();
    pairwise_flat(&data, &ids, &mut native, true);
    for i in 0..ids.len() {
        for j in 0..ids.len() {
            if i == j {
                continue;
            }
            let (a, b) = (pjrt.get(i, j), native.get(i, j));
            assert!(
                (a - b).abs() <= 2e-3 * (1.0 + b.abs()),
                "({i},{j}): pjrt {a} vs native {b}"
            );
        }
    }
}

#[test]
fn pjrt_full_build_reaches_native_recall() {
    require_artifacts!();
    // clustered data: low intrinsic dimension, so recall reflects the
    // runtime's correctness rather than NN-Descent's known high-dim limits
    let data = knng::dataset::clustered::SynthClustered::new(1500, 64, 8, 33).generate();
    let truth = knng::baseline::brute::brute_force_knn_sampled(&data, 10, 200, 5);

    let base = Params::default().with_k(10).with_seed(33).with_selection(SelectionKind::Turbo);
    let native =
        NnDescent::new(base.clone().with_compute(ComputeKind::Blocked)).build(&data).unwrap();
    let mut engine = PjrtEngine::open("artifacts").unwrap();
    let pjrt = NnDescent::new(base.with_compute(ComputeKind::Pjrt)).build_with_engine(
        &data,
        &mut engine,
        &mut NoTracer,
    );
    let rn = recall_against_truth(&native, &truth);
    let rp = recall_against_truth(&pjrt, &truth);
    assert!(rp > 0.9, "pjrt recall {rp}");
    assert!((rn - rp).abs() < 0.06, "native {rn} vs pjrt {rp} should be comparable");
    assert!(engine.executions > 0, "kernel must actually have run");
}

#[test]
fn tile_scanner_matches_native() {
    require_artifacts!();
    let data = SynthGaussian::single(1200, 64, 17).generate();
    let mut scanner = TileScanner::open("artifacts", 128, 1024, data.dim_pad()).unwrap();
    let queries: Vec<u32> = (0..100).collect();
    let corpus: Vec<u32> = (100..1100).collect();
    let out = scanner.scan(&data, &queries, &corpus).unwrap();
    assert_eq!(out.len(), 100 * 1000);
    for (qi, &q) in queries.iter().enumerate().step_by(17) {
        for (ci, &c) in corpus.iter().enumerate().step_by(131) {
            let expect = knng::distance::sq_l2_unrolled(data.row(q as usize), data.row(c as usize));
            let got = out[qi * 1000 + ci];
            assert!((got - expect).abs() <= 2e-3 * (1.0 + expect), "({qi},{ci}): {got} vs {expect}");
        }
    }
    // bounds checks
    let too_many: Vec<u32> = (0..200).collect();
    assert!(scanner.scan(&data, &too_many, &corpus).is_err());
}
