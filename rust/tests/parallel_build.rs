//! Parallel NN-Descent build — the cross-layer contracts:
//!
//! * `threads = 1` is **bit-identical** to the sequential engine
//!   (graph, σ, `FlopCounter`, per-iteration stats), asserted against
//!   the explicit-engine funnel which never routes parallel.
//! * `threads ∈ {2, 4}` builds are valid, deterministic, thread-count
//!   invariant, and land within 0.02 recall of the sequential build on
//!   the clustered corpus.
//! * The knob's precedence: explicit `Params::threads` / builder /
//!   `--threads` beat `PALLAS_BUILD_THREADS`, which beats the default.
//! * Sharded builds distribute whole-shard builds over the worker pool
//!   and stay bit-identical to the sequential shard loop.

use knng::api::{IndexBuilder, Searcher};
use knng::baseline::brute::brute_force_knn;
use knng::cachesim::trace::NoTracer;
use knng::config::schema::ComputeKind;
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::compute::NativeEngine;
use knng::nndescent::{BuildResult, NnDescent, Params};

fn corpus(n: usize, seed: u64) -> AlignedMatrix {
    let (data, _) = SynthClustered::new(n, 8, 6, seed).generate_labeled();
    data
}

/// The always-sequential reference: the explicit-engine funnel ignores
/// the threads knob by contract, so it is exactly the historical build.
fn sequential_reference(params: &Params, data: &AlignedMatrix) -> BuildResult {
    let mut engine = NativeEngine::new(params.compute);
    NnDescent::new(params.clone()).build_with_engine(data, &mut engine, &mut NoTracer)
}

/// Bit-level equality of two build results: graph strips (ids, distance
/// bits, flags), σ, flop counter, and the per-iteration work columns.
fn assert_builds_bit_identical(a: &BuildResult, b: &BuildResult, ctx: &str) {
    assert_eq!(a.iterations, b.iterations, "{ctx}: iterations");
    assert_eq!(a.stats.dist_evals, b.stats.dist_evals, "{ctx}: dist_evals");
    assert_eq!(a.stats.dim, b.stats.dim, "{ctx}: counter dim");
    assert_eq!(a.per_iter.len(), b.per_iter.len(), "{ctx}: per-iter rows");
    for (x, y) in a.per_iter.iter().zip(&b.per_iter) {
        assert_eq!(x.iter, y.iter, "{ctx}: iter index");
        assert_eq!(x.updates, y.updates, "{ctx}: iter {} updates", x.iter);
        assert_eq!(x.dist_evals, y.dist_evals, "{ctx}: iter {} evals", x.iter);
    }
    match (&a.reordering, &b.reordering) {
        (None, None) => {}
        (Some(ra), Some(rb)) => assert_eq!(ra.sigma, rb.sigma, "{ctx}: σ"),
        _ => panic!("{ctx}: one build reordered, the other did not"),
    }
    let g = &a.graph;
    let h = &b.graph;
    assert_eq!(g.n(), h.n(), "{ctx}");
    assert_eq!(g.k(), h.k(), "{ctx}");
    for u in 0..g.n() {
        assert_eq!(g.ids(u), h.ids(u), "{ctx}: node {u} ids");
        let da: Vec<u32> = g.dists(u).iter().map(|d| d.to_bits()).collect();
        let db: Vec<u32> = h.dists(u).iter().map(|d| d.to_bits()).collect();
        assert_eq!(da, db, "{ctx}: node {u} dists");
        assert_eq!(g.flags(u), h.flags(u), "{ctx}: node {u} flags");
    }
}

#[test]
fn t1_is_bit_identical_to_the_sequential_engine() {
    // with and without the reorder heuristic, across compute backends
    for (compute, reorder) in [
        (ComputeKind::Blocked, false),
        (ComputeKind::Blocked, true),
        (ComputeKind::Scalar, false),
    ] {
        let data = corpus(500, 3);
        let params = Params::default()
            .with_k(8)
            .with_seed(3)
            .with_compute(compute)
            .with_reorder(reorder)
            .with_threads(1);
        let seq = sequential_reference(&params, &data);
        let t1 = NnDescent::new(params.clone()).build(&data).unwrap();
        assert_builds_bit_identical(&seq, &t1, &format!("{compute:?}/reorder={reorder}"));
    }
}

#[test]
fn non_turbo_selections_keep_their_algorithm_and_run_sequentially() {
    // threads > 1 with naive/heap selection must not silently swap in
    // the turbo sampler: the build falls back to the configured
    // sequential implementation, bit-identical to a plain run
    use knng::config::schema::SelectionKind;
    for selection in [SelectionKind::Naive, SelectionKind::Heap] {
        let data = corpus(400, 31);
        let params =
            Params::default().with_k(6).with_seed(31).with_selection(selection).with_threads(4);
        let seq = sequential_reference(&params, &data);
        let got = NnDescent::new(params.clone()).build(&data).unwrap();
        assert_builds_bit_identical(&seq, &got, &format!("{selection:?} + threads=4"));
    }
}

#[test]
fn parallel_builds_are_valid_and_within_the_recall_gate() {
    let data = corpus(1200, 7);
    let truth = brute_force_knn(&data, 10);
    let base = Params::default().with_k(10).with_seed(7);
    let seq = NnDescent::new(base.clone().with_threads(1)).build(&data).unwrap();
    let seq_recall = recall_against_truth(&seq, &truth);
    assert!(seq_recall > 0.9, "sequential baseline recall {seq_recall}");
    for threads in [2usize, 4] {
        let par = NnDescent::new(base.clone().with_threads(threads)).build(&data).unwrap();
        par.graph.validate().unwrap();
        assert!(par.iterations >= 2, "T={threads}: suspiciously fast convergence");
        let r = recall_against_truth(&par, &truth);
        assert!(
            r > seq_recall - 0.02,
            "T={threads}: recall {r} more than 0.02 below sequential {seq_recall}"
        );
    }
}

#[test]
fn parallel_build_is_deterministic_and_thread_count_invariant() {
    let data = corpus(800, 11);
    let base = Params::default().with_k(8).with_seed(11).with_reorder(true);
    let t2a = NnDescent::new(base.clone().with_threads(2)).build(&data).unwrap();
    let t2b = NnDescent::new(base.clone().with_threads(2)).build(&data).unwrap();
    assert_builds_bit_identical(&t2a, &t2b, "T=2 repeat");
    // the counter-based phases make the thread count a pure perf knob
    let t4 = NnDescent::new(base.clone().with_threads(4)).build(&data).unwrap();
    assert_builds_bit_identical(&t2a, &t4, "T=2 vs T=4");
    assert!(t2a.reordering.is_some(), "reorder must compose with the parallel engine");
    t2a.reordering.as_ref().unwrap().validate().unwrap();
}

#[test]
fn env_var_sets_the_default_and_explicit_threads_win() {
    // Process-global state: this is the only test in the crate that
    // *sets* the variable, and every other build in this suite pins an
    // explicit thread count, which shields it from the env.
    let data = corpus(400, 19);
    let base = Params::default().with_k(6).with_seed(19);
    let explicit2 = NnDescent::new(base.clone().with_threads(2)).build(&data).unwrap();
    let explicit1 = NnDescent::new(base.clone().with_threads(1)).build(&data).unwrap();
    let prior = std::env::var("PALLAS_BUILD_THREADS").ok();
    std::env::set_var("PALLAS_BUILD_THREADS", "2");
    let via_env = NnDescent::new(base.clone()).build(&data).unwrap();
    let overridden = NnDescent::new(base.clone().with_threads(1)).build(&data).unwrap();
    match prior {
        Some(v) => std::env::set_var("PALLAS_BUILD_THREADS", v),
        None => std::env::remove_var("PALLAS_BUILD_THREADS"),
    }
    assert_builds_bit_identical(&explicit2, &via_env, "env default");
    assert_builds_bit_identical(&explicit1, &overridden, "explicit beats env");
    assert_eq!(knng::nndescent::resolve_build_threads(5), 5);
}

#[test]
fn builder_facade_carries_the_knob_end_to_end() {
    let data = corpus(600, 23);
    let params = Params::default().with_k(8).with_seed(23);
    let seq = IndexBuilder::new()
        .data_named(data.clone(), "clustered")
        .params(params.clone())
        .threads(1)
        .build()
        .unwrap();
    let par = IndexBuilder::new()
        .data_named(data.clone(), "clustered")
        .params(params)
        .threads(4)
        .build()
        .unwrap();
    assert_eq!(seq.len(), par.len());
    // both serve sane results over the same corpus; exact graphs differ
    // (phased vs immediate updates), quality must not
    let sp = Default::default();
    for qi in (0..600).step_by(97) {
        let (a, _) = seq.search(data.row_logical(qi), 5, &sp);
        let (b, _) = par.search(data.row_logical(qi), 5, &sp);
        assert_eq!(a[0].id, b[0].id, "query {qi}: self hit");
        assert!(b[0].dist < 1e-6, "query {qi}");
    }
    let t = par.telemetry().expect("built indexes carry telemetry");
    assert!(t.iterations >= 2);
}

#[test]
fn sharded_parallel_build_is_bit_identical_to_sequential_sharding() {
    let data = corpus(800, 29);
    let params = Params::default().with_k(6).with_seed(29);
    let seq = knng::api::ShardedSearcher::build(&data, 4, &params.clone().with_threads(1)).unwrap();
    let par = knng::api::ShardedSearcher::build(&data, 4, &params.with_threads(3)).unwrap();
    assert_eq!(seq.shard_sizes(), par.shard_sizes());
    let sp = Default::default();
    let queries = AlignedMatrix::from_rows(
        20,
        data.dim(),
        &(0..20).flat_map(|i| data.row_logical(i * 37).to_vec()).collect::<Vec<f32>>(),
    );
    let (a, sa) = seq.search_batch(&queries, 5, &sp);
    let (b, sb) = par.search_batch(&queries, 5, &sp);
    assert_eq!(sa.dist_evals, sb.dist_evals);
    for (qi, (ra, rb)) in a.iter().zip(&b).enumerate() {
        assert_eq!(ra.len(), rb.len(), "query {qi}");
        for (x, y) in ra.iter().zip(rb) {
            assert_eq!(x.id, y.id, "query {qi}");
            assert_eq!(x.dist.to_bits(), y.dist.to_bits(), "query {qi}");
        }
    }
}
