//! CLI integration: drive the `knng` binary end-to-end through its
//! subcommands (uses the test-built binary via CARGO_BIN_EXE).

use std::process::Command;

fn knng(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_knng"))
        .args(args)
        .output()
        .expect("spawn knng")
}

#[test]
fn help_and_info() {
    let out = knng(&["help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["build", "gen", "check", "info"] {
        assert!(text.contains(cmd), "help must list `{cmd}`");
    }

    let out = knng(&["info"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=20"), "defaults shown");
}

#[test]
fn build_from_flags_tsv() {
    let out = knng(&[
        "build",
        "--dataset", "clustered",
        "--n", "600",
        "--dim", "8",
        "--clusters", "4",
        "--k", "10",
        "--recall-queries", "50",
        "--tsv",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert!(header.starts_with("name\tdataset"));
    let row = lines.next().unwrap();
    let cols: Vec<&str> = row.split('\t').collect();
    assert_eq!(cols.len(), header.split('\t').count());
    let recall: f64 = cols.last().unwrap().parse().unwrap();
    assert!(recall > 0.9, "CLI recall {recall}");
}

#[test]
fn build_from_config_file() {
    let dir = std::env::temp_dir().join("knng_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        "name = \"cli-cfg\"\n[dataset]\nkind = \"gaussian\"\nn = 400\ndim = 8\n[run]\nk = 8\n",
    )
    .unwrap();
    let out = knng(&["build", "--config", cfg.to_str().unwrap(), "--recall-queries", "30"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cli-cfg"));
    assert!(text.contains("recall"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_writes_fvecs_roundtrip() {
    let dir = std::env::temp_dir().join("knng_cli_gen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.fvecs");
    let out = knng(&[
        "gen", "--dataset", "gaussian", "--n", "128", "--dim", "24",
        "--out", path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let m = knng::dataset::fvecs::read_fvecs(&path, usize::MAX).unwrap();
    assert_eq!((m.n(), m.dim()), (128, 24));
    // and the CLI can consume its own output
    let out = knng(&[
        "build", "--dataset", "fvecs", "--path", path.to_str().unwrap(),
        "--n", "128", "--k", "8", "--recall-queries", "20",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn index_bundle_build_query_roundtrip() {
    // the serving workflow: gen → build --save-index → query --index,
    // checked for recall ≥ 0.9 at k=10 against in-process brute force
    let dir = std::env::temp_dir().join("knng_cli_index");
    std::fs::create_dir_all(&dir).unwrap();
    let data_path = dir.join("corpus.fvecs");
    let index_path = dir.join("corpus.knni");

    let out = knng(&[
        "gen", "--dataset", "clustered", "--n", "800", "--dim", "8",
        "--clusters", "8", "--seed", "12",
        "--out", data_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));

    let out = knng(&[
        "build", "--dataset", "fvecs", "--path", data_path.to_str().unwrap(),
        "--n", "800", "--k", "16", "--reorder", "--recall-queries", "0",
        "--save-index", index_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    assert!(index_path.exists(), "bundle must be written");

    // query the index with the corpus itself (k=11 ⇒ self + 10 neighbors)
    let out = knng(&[
        "query", "--index", index_path.to_str().unwrap(),
        "--batch", data_path.to_str().unwrap(), "--k", "11", "--stats",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("qps"), "aggregate stats on stderr: {stderr}");
    assert!(stderr.contains("evals/query"), "aggregate stats on stderr: {stderr}");

    // parse result ids (original id space) and score against brute force
    let data = knng::dataset::fvecs::read_fvecs(&data_path, usize::MAX).unwrap();
    let k = 10;
    let mut hits = 0usize;
    let mut queries = 0usize;
    for line in String::from_utf8_lossy(&out.stdout).lines() {
        let mut cols = line.split('\t');
        let qi: usize = cols.next().unwrap().parse().unwrap();
        let found: Vec<u32> = cols
            .map(|c| c.split(':').next().unwrap().parse().unwrap())
            .filter(|&v| v as usize != qi) // drop the self hit
            .take(k)
            .collect();
        let mut exact: Vec<(u32, f32)> = (0..data.n() as u32)
            .filter(|&v| v as usize != qi)
            .map(|v| {
                (v, knng::distance::sq_l2_unrolled(data.row(qi), data.row(v as usize)))
            })
            .collect();
        exact.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        hits += exact[..k].iter().filter(|(v, _)| found.contains(v)).count();
        queries += 1;
    }
    assert_eq!(queries, 800, "one output line per query");
    let recall = hits as f64 / (queries * k) as f64;
    assert!(recall >= 0.9, "index-serving recall {recall} < 0.9");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn serve_mode_matches_plain_batched_output() {
    // gen → build --save-index → query twice: once through the plain
    // batched path, once through --serve (thread-per-shard pool +
    // micro-batching front). Same queries, so stdout must be identical
    // line for line — the CLI-level spelling of the bit-equality
    // guarantee.
    let dir = std::env::temp_dir().join("knng_cli_serve");
    std::fs::create_dir_all(&dir).unwrap();
    let data_path = dir.join("corpus.fvecs");
    let index_path = dir.join("corpus.knni");

    let out = knng(&[
        "gen", "--dataset", "clustered", "--n", "500", "--dim", "8",
        "--clusters", "6", "--seed", "23",
        "--out", data_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let out = knng(&[
        "build", "--dataset", "fvecs", "--path", data_path.to_str().unwrap(),
        "--n", "500", "--k", "12", "--reorder", "--recall-queries", "0",
        "--save-index", index_path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));

    let plain = knng(&[
        "query", "--index", index_path.to_str().unwrap(),
        "--batch", data_path.to_str().unwrap(), "--k", "5",
    ]);
    assert!(plain.status.success(), "stderr: {}", String::from_utf8_lossy(&plain.stderr));

    let served = knng(&[
        "query", "--index", index_path.to_str().unwrap(),
        "--batch", data_path.to_str().unwrap(), "--k", "5",
        "--serve", "--threads", "2", "--max-batch", "64", "--batch-window", "2000",
    ]);
    assert!(served.status.success(), "stderr: {}", String::from_utf8_lossy(&served.stderr));

    assert_eq!(
        String::from_utf8_lossy(&plain.stdout),
        String::from_utf8_lossy(&served.stdout),
        "serve mode must answer exactly like the plain batched path"
    );
    let stderr = String::from_utf8_lossy(&served.stderr);
    assert!(stderr.contains("served 500 queries"), "serve summary on stderr: {stderr}");
    assert!(stderr.contains("window"), "serve summary on stderr: {stderr}");
    // a single-shard index clamps the worker count, with a note
    assert!(stderr.contains("clamped"), "clamp note on stderr: {stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_input_fails_cleanly() {
    let out = knng(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));

    let out = knng(&["build", "--selection", "psychic"]);
    assert!(!out.status.success());

    let out = knng(&["gen", "--dataset", "gaussian"]); // missing --out
    assert!(!out.status.success());
}
