//! CLI integration: drive the `knng` binary end-to-end through its
//! subcommands (uses the test-built binary via CARGO_BIN_EXE).

use std::process::Command;

fn knng(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_knng"))
        .args(args)
        .output()
        .expect("spawn knng")
}

#[test]
fn help_and_info() {
    let out = knng(&["help"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["build", "gen", "check", "info"] {
        assert!(text.contains(cmd), "help must list `{cmd}`");
    }

    let out = knng(&["info"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("k=20"), "defaults shown");
}

#[test]
fn build_from_flags_tsv() {
    let out = knng(&[
        "build",
        "--dataset", "clustered",
        "--n", "600",
        "--dim", "8",
        "--clusters", "4",
        "--k", "10",
        "--recall-queries", "50",
        "--tsv",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    let mut lines = text.lines();
    let header = lines.next().unwrap();
    assert!(header.starts_with("name\tdataset"));
    let row = lines.next().unwrap();
    let cols: Vec<&str> = row.split('\t').collect();
    assert_eq!(cols.len(), header.split('\t').count());
    let recall: f64 = cols.last().unwrap().parse().unwrap();
    assert!(recall > 0.9, "CLI recall {recall}");
}

#[test]
fn build_from_config_file() {
    let dir = std::env::temp_dir().join("knng_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.toml");
    std::fs::write(
        &cfg,
        "name = \"cli-cfg\"\n[dataset]\nkind = \"gaussian\"\nn = 400\ndim = 8\n[run]\nk = 8\n",
    )
    .unwrap();
    let out = knng(&["build", "--config", cfg.to_str().unwrap(), "--recall-queries", "30"]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("cli-cfg"));
    assert!(text.contains("recall"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gen_writes_fvecs_roundtrip() {
    let dir = std::env::temp_dir().join("knng_cli_gen");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("g.fvecs");
    let out = knng(&[
        "gen", "--dataset", "gaussian", "--n", "128", "--dim", "24",
        "--out", path.to_str().unwrap(),
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let m = knng::dataset::fvecs::read_fvecs(&path, usize::MAX).unwrap();
    assert_eq!((m.n(), m.dim()), (128, 24));
    // and the CLI can consume its own output
    let out = knng(&[
        "build", "--dataset", "fvecs", "--path", path.to_str().unwrap(),
        "--n", "128", "--k", "8", "--recall-queries", "20",
    ]);
    assert!(out.status.success(), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn bad_input_fails_cleanly() {
    let out = knng(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));

    let out = knng(&["build", "--selection", "psychic"]);
    assert!(!out.status.success());

    let out = knng(&["gen", "--dataset", "gaussian"]); // missing --out
    assert!(!out.status.success());
}
