//! Cross-module integration tests: full builds over every dataset
//! family × selection × compute × reorder combination, result-semantics
//! invariants, and config-file round trips.

use knng::api::{EvalOptions, IndexBuilder};
use knng::baseline::brute::brute_force_knn_sampled;
use knng::config::schema::{ComputeKind, SelectionKind};
use knng::config::{DatasetSpec, ExperimentConfig};
use knng::dataset::from_spec;
use knng::metrics::recall::recall_against_truth;
use knng::nndescent::{NnDescent, Params};

#[test]
fn matrix_of_variants_converges_on_clustered_data() {
    let ds = from_spec(&DatasetSpec::Clustered { n: 900, dim: 16, clusters: 6, seed: 41 }).unwrap();
    let truth = brute_force_knn_sampled(&ds.data, 10, 150, 3);
    for sel in [SelectionKind::Naive, SelectionKind::Heap, SelectionKind::Turbo] {
        for comp in [ComputeKind::Scalar, ComputeKind::Unrolled, ComputeKind::Blocked] {
            for reorder in [false, true] {
                let params = Params::default()
                    .with_k(10)
                    .with_seed(41)
                    .with_selection(sel)
                    .with_compute(comp)
                    .with_reorder(reorder);
                let r = NnDescent::new(params).build(&ds.data).unwrap();
                r.graph.validate().unwrap_or_else(|e| {
                    panic!("{sel:?}/{comp:?}/reorder={reorder}: graph invalid: {e}")
                });
                let rec = recall_against_truth(&r, &truth);
                assert!(
                    rec > 0.93,
                    "{sel:?}/{comp:?}/reorder={reorder}: recall {rec}"
                );
            }
        }
    }
}

#[test]
fn every_dataset_family_builds() {
    let specs = [
        DatasetSpec::Gaussian { n: 500, dim: 24, single: true, seed: 1 },
        DatasetSpec::Gaussian { n: 500, dim: 12, single: false, seed: 2 },
        DatasetSpec::Clustered { n: 500, dim: 8, clusters: 5, seed: 3 },
        DatasetSpec::Mnist { n: 300, path: None, seed: 4 },
        DatasetSpec::Audio { n: 300, dim: 48, seed: 5 },
    ];
    for spec in specs {
        let ds = from_spec(&spec).unwrap();
        let r = NnDescent::new(Params::default().with_k(8).with_seed(9)).build(&ds.data).unwrap();
        assert!(r.iterations >= 2, "{}: converged suspiciously fast", ds.name);
        r.graph.validate().unwrap();
        // distances in results must be true squared-L2 of the rows
        for u in (0..ds.n()).step_by(71) {
            for (v, d) in r.neighbors_original(u) {
                let expect =
                    knng::distance::sq_l2_unrolled(ds.data.row(u), ds.data.row(v as usize));
                assert!((d - expect).abs() < 1e-3 * (1.0 + expect), "{}: {u}->{v}", ds.name);
            }
        }
    }
}

#[test]
fn reordered_and_plain_runs_agree_on_quality_not_layout() {
    let ds = from_spec(&DatasetSpec::Clustered { n: 800, dim: 8, clusters: 8, seed: 13 }).unwrap();
    let base = Params::default().with_k(12).with_seed(13);
    let plain = NnDescent::new(base.clone()).build(&ds.data).unwrap();
    let reord = NnDescent::new(base.with_reorder(true)).build(&ds.data).unwrap();
    let r = reord.reordering.as_ref().expect("must reorder");
    r.validate().unwrap();
    // permutation must be non-trivial on clustered data
    let moved = r.sigma.iter().enumerate().filter(|(i, &s)| s as usize != *i).count();
    assert!(moved > 100, "only {moved} nodes moved");
    // but result quality must be preserved
    let truth = brute_force_knn_sampled(&ds.data, 12, 100, 1);
    let (rp, rr) = (
        recall_against_truth(&plain, &truth),
        recall_against_truth(&reord, &truth),
    );
    assert!(rr > 0.95 && (rp - rr).abs() < 0.04, "plain {rp} vs reordered {rr}");
}

#[test]
fn pipeline_runs_bundled_configs() {
    // the bundled configs must stay loadable and runnable (shrunk)
    for entry in std::fs::read_dir("configs").unwrap() {
        let path = entry.unwrap().path();
        if path.extension().is_none_or(|e| e != "toml") {
            continue;
        }
        let mut cfg = ExperimentConfig::load(&path).unwrap();
        // shrink for test speed, keep everything else
        cfg.dataset = match cfg.dataset {
            DatasetSpec::Gaussian { dim, single, seed, .. } =>
                DatasetSpec::Gaussian { n: 400, dim, single, seed },
            DatasetSpec::Clustered { dim, clusters, seed, .. } =>
                DatasetSpec::Clustered { n: 400, dim, clusters, seed },
            DatasetSpec::Mnist { path, seed, .. } => DatasetSpec::Mnist { n: 300, path, seed },
            DatasetSpec::Audio { dim, seed, .. } => DatasetSpec::Audio { n: 300, dim, seed },
            other => other,
        };
        let index = IndexBuilder::from_config(&cfg)
            .build()
            .unwrap_or_else(|e| panic!("{}: {e:#}", path.display()));
        let report = index.evaluate(&EvalOptions::new().with_recall_queries(50).with_seed(2));
        assert!(report.recall.unwrap() > 0.8, "{}: recall {:?}", path.display(), report.recall);
    }
}

#[test]
fn determinism_across_full_pipeline() {
    let cfg = ExperimentConfig::from_str(
        r#"
        name = "det"
        [dataset]
        kind = "clustered"
        n = 500
        dim = 8
        clusters = 4
        seed = 99
        [run]
        k = 10
        seed = 99
        reorder = true
        "#,
    )
    .unwrap();
    let eval = EvalOptions::new().with_recall_queries(40).with_seed(1);
    let a = IndexBuilder::from_config(&cfg).build().unwrap().evaluate(&eval);
    let b = IndexBuilder::from_config(&cfg).build().unwrap().evaluate(&eval);
    assert_eq!(a.dist_evals, b.dist_evals);
    assert_eq!(a.iterations, b.iterations);
    assert_eq!(a.recall, b.recall);
}
