//! Network-stack integration over loopback (`127.0.0.1:0`, ephemeral
//! ports): the KNNQv1 bit-identity contract (wire answers == in-process
//! `ServeFront` answers == direct `search_batch`), per-request `k`
//! accept/reject, the cross-window answer cache's transparency, typed
//! rejections for mismatched routing/dim, graceful shutdown, and a
//! fuzz-style malformed-frame suite asserting the server keeps serving
//! well-formed requests after every kind of wire abuse.

use knng::api::{
    FrontConfig, KMismatch, Neighbor, Searcher, ServeFront, ShardPool, ShardedSearcher,
};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::net::{wire, ErrorCode, Frame, NetClient, NetServer, ServerConfig, ServerHandle};
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::testing::assert_neighbors_bitwise_eq;
use std::io::Write;
use std::net::TcpStream;
use std::time::Duration;

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

/// A small-window front config so wire requests exercise real batching.
fn front_cfg(k: usize, params: SearchParams) -> FrontConfig {
    FrontConfig {
        k,
        params,
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    }
}

/// Open a raw connection for wire-level abuse.
fn raw_conn(
    addr: std::net::SocketAddr,
    f: impl FnOnce(&mut TcpStream, &mut std::io::BufReader<TcpStream>),
) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    f(&mut writer, &mut reader);
}

/// Pool + front + listener on an ephemeral loopback port.
fn spawn_server(sharded: &ShardedSearcher, cfg: FrontConfig) -> ServerHandle {
    let pool = ShardPool::new(sharded, 2).unwrap();
    let front = ServeFront::spawn(pool, sharded.dim(), cfg).unwrap();
    let server_cfg = ServerConfig {
        workers: 2,
        read_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    NetServer::bind("127.0.0.1:0", front, server_cfg).unwrap().spawn().unwrap()
}

#[test]
fn loopback_is_bit_identical_to_in_process_front() {
    // the acceptance criterion: the same query tile answered over
    // loopback, through an in-process front, and by direct
    // search_batch must be bit-identical — the wire adds transport,
    // never computation
    let (all, _) = SynthClustered::new(700, 8, 4, 91).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = slice_rows(&all, 600, 50);
    let params = Params::default().with_k(10).with_seed(91).with_reorder(true);
    let k = 6;
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 4, &params).unwrap();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);

    let pool = ShardPool::new(&sharded, 2).unwrap();
    let front = ServeFront::spawn(pool, corpus.dim(), front_cfg(k, sp)).unwrap();
    let tickets: Vec<_> = (0..queries.n())
        .map(|qi| front.submit(queries.row_logical(qi).to_vec()).unwrap())
        .collect();
    let in_process: Vec<Vec<Neighbor>> =
        tickets.into_iter().map(|t| t.wait().unwrap().neighbors).collect();
    front.shutdown();
    assert_neighbors_bitwise_eq(&expect, &in_process, "in-process front vs direct");

    let handle = spawn_server(&sharded, front_cfg(k, sp));
    let mut client = NetClient::connect(handle.addr()).unwrap();
    let info = client.ping().unwrap();
    assert_eq!(info.n, 600);
    assert_eq!(info.dim, 8);
    assert_eq!(info.k, k as u32);
    let (wire_results, windows) = client.query_batch(&queries, k, None).unwrap();
    assert_eq!(windows.len(), queries.n());
    for w in &windows {
        assert!(w.unique >= 1 && w.unique <= w.requests);
    }
    assert_neighbors_bitwise_eq(&expect, &wire_results, "loopback vs direct");
    assert_neighbors_bitwise_eq(&in_process, &wire_results, "loopback vs in-process front");

    drop(client);
    let (net, totals) = handle.stop().unwrap();
    assert!(net.connections >= 1);
    assert_eq!(net.queries, queries.n() as u64);
    assert_eq!(net.protocol_errors, 0);
    assert_eq!(totals.queries, queries.n() as u64);
}

#[test]
fn wire_rejects_mismatched_k_route_and_dim_with_typed_errors() {
    let (all, _) = SynthClustered::new(500, 8, 4, 93).generate_labeled();
    let corpus = slice_rows(&all, 0, 440);
    let queries = slice_rows(&all, 440, 20);
    let params = Params::default().with_k(8).with_seed(93);
    let k = 6;
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 2, &params).unwrap();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);

    let handle = spawn_server(&sharded, front_cfg(k, sp));
    let mut client = NetClient::connect(handle.addr()).unwrap();

    // reject: per-request k that the front does not serve
    let err = client.query_batch(&queries, 3, None).unwrap_err();
    let rej = err.downcast_ref::<knng::net::ServerRejection>().expect("typed rejection");
    assert_eq!(rej.code, ErrorCode::MismatchedK);
    assert_eq!(rej.detail, k as u32, "detail carries the served k");

    // reject: routing the server was not configured for
    let err = client.query_batch(&queries, k, Some(2)).unwrap_err();
    let rej = err.downcast_ref::<knng::net::ServerRejection>().unwrap();
    assert_eq!(rej.code, ErrorCode::MismatchedRoute);
    assert_eq!(rej.detail, 0, "detail carries the configured fan-out (0 = full)");

    // reject: wrong dimensionality
    let skinny = AlignedMatrix::from_rows(2, 3, &[0.0; 6]);
    let err = client.query_batch(&skinny, k, None).unwrap_err();
    let rej = err.downcast_ref::<knng::net::ServerRejection>().unwrap();
    assert_eq!(rej.code, ErrorCode::BadQuery);
    assert_eq!(rej.detail, 8, "detail carries the served dim");

    // accept: the same connection still serves after three rejections
    let (results, _) = client.query_batch(&queries, k, None).unwrap();
    assert_neighbors_bitwise_eq(&expect, &results, "accept path after rejects");

    drop(client);
    let (net, _) = handle.stop().unwrap();
    assert_eq!(net.protocol_errors, 0, "typed rejections are not protocol errors");
}

#[test]
fn submit_with_k_accepts_matching_and_rejects_mismatched() {
    // the in-process half of the per-request-k contract: mismatched k
    // is a typed rejection (windows share one search_batch call, so
    // the front never re-buckets by k)
    let (all, _) = SynthClustered::new(220, 8, 4, 95).generate_labeled();
    let corpus = slice_rows(&all, 0, 200);
    let sharded =
        ShardedSearcher::build(&corpus, 2, &Params::default().with_k(8).with_seed(95)).unwrap();
    let pool = ShardPool::new(&sharded, 2).unwrap();
    let cfg = FrontConfig { k: 5, ..Default::default() };
    let front = ServeFront::spawn(pool, corpus.dim(), cfg).unwrap();
    assert_eq!(front.serving_k(), 5);
    assert_eq!(front.dim(), corpus.dim());
    assert_eq!(front.corpus_len(), 200);
    assert_eq!(front.route_top_m(), None);

    let row = all.row_logical(210).to_vec();
    let err = front.submit_with_k(row.clone(), 9).unwrap_err();
    let mismatch = err.downcast_ref::<KMismatch>().expect("typed KMismatch");
    assert_eq!(*mismatch, KMismatch { requested: 9, serving: 5 });

    let ticket = front.submit_with_k(row, 5).unwrap();
    assert_eq!(ticket.wait().unwrap().neighbors.len(), 5);
    let totals = front.shutdown();
    assert_eq!(totals.queries, 1, "rejected submissions never reach a window");
}

#[test]
fn answer_cache_is_bit_transparent_and_counts_hits() {
    // cache-on vs cache-off answers must be bit-identical (the cache
    // stores final Neighbors only); repeats hit without touching the
    // searcher
    let (all, _) = SynthClustered::new(700, 8, 4, 97).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = slice_rows(&all, 600, 40);
    let params = Params::default().with_k(10).with_seed(97);
    let k = 5;
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 2, &params).unwrap();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);

    for cache in [0usize, 64] {
        let pool = ShardPool::new(&sharded, 2).unwrap();
        let cfg = FrontConfig { answer_cache: cache, ..front_cfg(k, sp) };
        let front = ServeFront::spawn(pool, corpus.dim(), cfg).unwrap();
        for round in 0..2 {
            let tickets: Vec<_> = (0..queries.n())
                .map(|qi| front.submit(queries.row_logical(qi).to_vec()).unwrap())
                .collect();
            let answers: Vec<Vec<Neighbor>> =
                tickets.into_iter().map(|t| t.wait().unwrap().neighbors).collect();
            assert_neighbors_bitwise_eq(
                &expect,
                &answers,
                &format!("cache={cache} round={round}"),
            );
        }
        let totals = front.shutdown();
        assert_eq!(totals.queries, 2 * queries.n() as u64);
        if cache == 0 {
            assert_eq!(totals.cache_hits, 0, "disabled cache never hits");
        } else {
            // round 1 populates (all 40 distinct queries fit in 64
            // slots), round 2 answers every unique from the cache
            assert_eq!(totals.cache_hits, queries.n() as u64);
        }
    }
}

#[test]
fn shutdown_frame_acks_drains_and_stops() {
    let (all, _) = SynthClustered::new(400, 8, 4, 99).generate_labeled();
    let corpus = slice_rows(&all, 0, 350);
    let queries = slice_rows(&all, 350, 10);
    let params = Params::default().with_k(8).with_seed(99);
    let k = 4;
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 2, &params).unwrap();

    let handle = spawn_server(&sharded, front_cfg(k, sp));
    let addr = handle.addr();
    let mut client = NetClient::connect(addr).unwrap();
    let (results, _) = client.query_batch(&queries, k, None).unwrap();
    assert_eq!(results.len(), queries.n());
    client.shutdown_server().unwrap(); // acked before the drain

    let (net, totals) = handle.join().unwrap();
    assert!(net.frames >= 2, "query + shutdown both counted");
    assert_eq!(totals.queries, queries.n() as u64, "in-flight windows drained");

    // the listener is gone: new connections are refused, or die on
    // their first read if the OS raced one into the backlog
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(stream) => {
            stream.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = stream;
            let mut buf = Vec::new();
            wire::write_frame(&mut buf, &Frame::Ping { token: 1 }).unwrap();
            let _ = writer.write_all(&buf);
            assert!(
                wire::read_frame(&mut reader, wire::DEFAULT_MAX_FRAME).is_err(),
                "nothing may answer after shutdown"
            );
        }
    }
}

#[test]
fn malformed_frames_get_typed_errors_and_never_wedge_the_server() {
    // the fuzz-style robustness gate: truncated frames, oversized
    // length prefixes, wrong magic/version, raw garbage, and mid-frame
    // disconnects — after all of it the server must still answer a
    // fresh well-formed request (no panic, no wedge)
    let (all, _) = SynthClustered::new(500, 8, 4, 101).generate_labeled();
    let corpus = slice_rows(&all, 0, 450);
    let queries = slice_rows(&all, 450, 10);
    let params = Params::default().with_k(8).with_seed(101);
    let k = 4;
    let sp = SearchParams::default();
    let sharded = ShardedSearcher::build(&corpus, 2, &params).unwrap();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);

    let handle = spawn_server(&sharded, front_cfg(k, sp));
    let addr = handle.addr();

    // 1) wrong magic: typed Malformed reply, connection keeps serving
    raw_conn(addr, |writer, reader| {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &Frame::Ping { token: 5 }).unwrap();
        buf[4] = b'X'; // first magic byte (after the 4 B length prefix)
        writer.write_all(&buf).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        let Frame::Error(e) = reply else { panic!("expected an error frame, got {reply:?}") };
        assert_eq!(e.code, ErrorCode::Malformed);
        // same connection, well-formed follow-up: still answered
        let mut ok = Vec::new();
        wire::write_frame(&mut ok, &Frame::Ping { token: 6 }).unwrap();
        writer.write_all(&ok).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        assert!(matches!(reply, Frame::Pong { token: 6, .. }), "got {reply:?}");
    });

    // 2) wrong version: typed UnsupportedVersion with the offered
    //    version as detail, connection keeps serving
    raw_conn(addr, |writer, reader| {
        let mut buf = Vec::new();
        wire::write_frame(&mut buf, &Frame::Ping { token: 7 }).unwrap();
        buf[8] = 9; // version byte
        writer.write_all(&buf).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        let Frame::Error(e) = reply else { panic!("expected an error frame, got {reply:?}") };
        assert_eq!(e.code, ErrorCode::UnsupportedVersion);
        assert_eq!(e.detail, 9);
        let mut ok = Vec::new();
        wire::write_frame(&mut ok, &Frame::Ping { token: 8 }).unwrap();
        writer.write_all(&ok).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        assert!(matches!(reply, Frame::Pong { token: 8, .. }), "got {reply:?}");
    });

    // 3) oversized length prefix: typed Oversized, then the server
    //    closes (the stream can no longer be framed)
    raw_conn(addr, |writer, reader| {
        writer.write_all(&u32::MAX.to_le_bytes()).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        let Frame::Error(e) = reply else { panic!("expected an error frame, got {reply:?}") };
        assert_eq!(e.code, ErrorCode::Oversized);
        assert!(
            wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).is_err(),
            "a desynced connection must be closed"
        );
    });

    // 4) undersized length prefix: typed Malformed, then closed
    raw_conn(addr, |writer, reader| {
        writer.write_all(&3u32.to_le_bytes()).unwrap();
        let reply = wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).unwrap();
        let Frame::Error(e) = reply else { panic!("expected an error frame, got {reply:?}") };
        assert_eq!(e.code, ErrorCode::Malformed);
        assert!(wire::read_frame(reader, wire::DEFAULT_MAX_FRAME).is_err());
    });

    // 5) mid-frame disconnect: promise 64 payload bytes, send 10, hang up
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(&64u32.to_le_bytes()).unwrap();
        stream.write_all(&[0u8; 10]).unwrap();
        drop(stream);
    }

    // 6) raw ASCII garbage (reads as a huge length prefix), then hang up
    {
        let mut stream = TcpStream::connect(addr).unwrap();
        stream.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
        drop(stream);
    }

    // after all the abuse: a fresh well-formed client gets the exact
    // bit-identical answers
    let mut client = NetClient::connect(addr).unwrap();
    let (results, _) = client.query_batch(&queries, k, None).unwrap();
    assert_neighbors_bitwise_eq(&expect, &results, "served after wire abuse");
    drop(client);
    let (net, _) = handle.stop().unwrap();
    assert!(net.protocol_errors >= 4, "each typed rejection counted");
}
