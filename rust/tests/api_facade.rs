//! `api` facade integration: sharded-vs-single equivalence (the S=1
//! bit-identity and S>1 recall acceptance gates), id-space guarantees
//! under reorder, and builder fallibility.

use knng::api::{EvalOptions, IndexBuilder, OriginalId, Searcher, ShardedSearcher};
use knng::config::schema::ComputeKind;
use knng::config::DatasetSpec;
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::metrics::recall::{exact_neighbor_ids, recall_vs_exact};
use knng::nndescent::{NnDescent, Params};
use knng::search::{GraphIndex, SearchParams};
use knng::testing::{check_result, Config};

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

#[test]
fn sharded_s1_is_bit_identical_to_graph_index_batch() {
    // the acceptance criterion: one shard sees the whole corpus and the
    // merge is the identity, so ids AND distance bits must match
    // GraphIndex::search_batch exactly, as must the aggregate work.
    let (all, _) = SynthClustered::new(1400, 16, 8, 17).generate_labeled();
    let corpus = slice_rows(&all, 0, 1200);
    let queries = slice_rows(&all, 1200, 200);
    let params = Params::default().with_k(16).with_seed(17);

    let result = NnDescent::new(params.clone()).build(&corpus).unwrap();
    let single = GraphIndex::new(corpus.clone(), result.graph);
    let sharded = ShardedSearcher::build(&corpus, 1, &params).unwrap();
    assert_eq!(sharded.shard_count(), 1);

    for sp in [
        SearchParams::default(),
        SearchParams { ef: 16, ..Default::default() },
        SearchParams { ef: 128, seeds: 4, ..Default::default() },
        SearchParams { probes: 64, ..Default::default() },
    ] {
        let (raw, raw_stats) = GraphIndex::search_batch(&single, &queries, 10, &sp);
        let (typed, typed_stats) = sharded.search_batch(&queries, 10, &sp);
        assert_eq!(raw.len(), typed.len());
        for (qi, (r, t)) in raw.iter().zip(&typed).enumerate() {
            assert_eq!(r.len(), t.len(), "ef={} query {qi} arity", sp.ef);
            for (j, (&(v, d), nb)) in r.iter().zip(t).enumerate() {
                assert_eq!(nb.id, OriginalId(v), "ef={} query {qi} rank {j} id", sp.ef);
                assert_eq!(
                    nb.dist.to_bits(),
                    d.to_bits(),
                    "ef={} query {qi} rank {j} distance bits",
                    sp.ef
                );
            }
        }
        assert_eq!(raw_stats.dist_evals, typed_stats.dist_evals, "aggregate evals");
        assert_eq!(raw_stats.expansions, typed_stats.expansions, "aggregate expansions");
    }
}

#[test]
fn sharded_s4_recall_within_002_of_single_on_clustered() {
    // sharding may cost at most 0.02 recall on the clustered config
    let (all, _) = SynthClustered::new(2200, 16, 8, 29).generate_labeled();
    let corpus = slice_rows(&all, 0, 2000);
    let queries = slice_rows(&all, 2000, 200);
    let k = 10;
    let params = Params::default().with_k(16).with_seed(29).with_reorder(true);

    let single = IndexBuilder::new()
        .data_named(corpus.clone(), "clustered")
        .params(params.clone())
        .build()
        .unwrap();
    let sharded = ShardedSearcher::build(&corpus, 4, &params).unwrap();
    assert_eq!(sharded.shard_sizes().iter().sum::<usize>(), 2000);

    let sp = SearchParams::default();
    let (single_res, _) = single.search_batch(&queries, k, &sp);
    let (sharded_res, _) = sharded.search_batch(&queries, k, &sp);

    // the shared recall definition the bench's 0.02 gate also uses
    let truth = exact_neighbor_ids(&corpus, &queries, k);
    let rs = recall_vs_exact(&single_res, &truth);
    let rsh = recall_vs_exact(&sharded_res, &truth);
    assert!(rs > 0.9, "single-index recall {rs} suspiciously low");
    assert!(rsh >= rs - 0.02, "sharded recall {rsh} dropped > 0.02 below single {rs}");
}

#[test]
fn property_sharded_results_are_valid_and_s1_matches_single() {
    // randomized configs: n, shard count, k, ef — invariants that must
    // hold for every draw. Few cases: each runs a full (small) build.
    check_result(Config::cases(6).with_seed(0xA91), "sharded invariants", |g| {
        let n = g.usize_in(80..240);
        let shards = g.usize_in(1..5).min(n / 2);
        let k = g.usize_in(3..9);
        let ef = g.usize_in(16..64);
        let (data, _) = SynthClustered::new(n, 8, 4, g.u64()).generate_labeled();
        let params = Params::default().with_k(10).with_seed(7);
        let sharded = ShardedSearcher::build(&data, shards, &params)
            .map_err(|e| format!("build failed: {e}"))?;
        let sp = SearchParams { ef, ..Default::default() };

        // query a handful of corpus rows
        for qi in [0usize, n / 3, n - 1] {
            let (res, stats) = sharded.search(data.row_logical(qi), k, &sp);
            if res.len() != k.min(n) {
                return Err(format!("n={n} S={shards}: got {} results for k={k}", res.len()));
            }
            if stats.dist_evals == 0 {
                return Err("no distance evaluations recorded".into());
            }
            // sorted ascending, ids in range, unique
            for w in res.windows(2) {
                if w[0].dist > w[1].dist {
                    return Err(format!("unsorted results at n={n} S={shards}"));
                }
                if w[0].id == w[1].id {
                    return Err(format!("duplicate id {} at n={n} S={shards}", w[0].id));
                }
            }
            if res.iter().any(|nb| nb.id.index() >= n) {
                return Err(format!("id out of range at n={n} S={shards}"));
            }
            if res[0].id.index() != qi || res[0].dist > 1e-6 {
                return Err(format!("self hit missing for row {qi} at n={n} S={shards}"));
            }
        }

        // S=1 must agree with a directly-built single index, bit for bit
        if shards == 1 {
            let result =
                NnDescent::new(params).build(&data).map_err(|e| format!("single: {e}"))?;
            let single = GraphIndex::new(data.clone(), result.graph);
            for qi in [0usize, n / 2] {
                let (raw, _) = GraphIndex::search(&single, data.row_logical(qi), k, &sp);
                let (typed, _) = sharded.search(data.row_logical(qi), k, &sp);
                for (&(v, d), nb) in raw.iter().zip(&typed) {
                    if nb.id != OriginalId(v) || nb.dist.to_bits() != d.to_bits() {
                        return Err(format!("S=1 divergence at n={n} qi={qi}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn facade_serves_original_ids_under_reorder_end_to_end() {
    let index = IndexBuilder::new()
        .dataset(DatasetSpec::Clustered { n: 700, dim: 8, clusters: 6, seed: 3 })
        .params(Params::default().with_k(10).with_seed(3).with_reorder(true))
        .build()
        .unwrap();
    assert!(index.is_reordered());
    let report = index.evaluate(&EvalOptions::new().with_recall_queries(60).with_seed(2));
    assert!(report.recall.unwrap() > 0.9, "recall {:?}", report.recall);

    // the working layout really is permuted, yet every search answers in
    // original ids: row w of the working data is original node σ⁻¹(w)
    let sp = SearchParams::default();
    for w in (0..700usize).step_by(97) {
        let (res, _) = index.search(index.data().row_logical(w), 1, &sp);
        let expect = index.to_original(knng::api::WorkingId(w as u32));
        assert_eq!(res[0].id, expect, "working row {w} must answer as its original id");
    }
}

#[test]
fn builder_is_fallible_end_to_end() {
    // pjrt without the feature/engine: Err with an actionable message
    let res = IndexBuilder::new()
        .dataset(DatasetSpec::Gaussian { n: 100, dim: 8, single: true, seed: 1 })
        .params(Params::default().with_k(5).with_compute(ComputeKind::Pjrt))
        .build();
    assert!(res.is_err());

    // missing dataset file: Err, not panic
    let res = IndexBuilder::new()
        .dataset(DatasetSpec::Fvecs { path: "/nonexistent/corpus.fvecs".into(), limit: 10 })
        .build();
    assert!(res.is_err());
}
