//! Recall-vs-fanout gate for centroid-routed serving: on clustered
//! data, a k-means sharded searcher answering from only the top-2 of 4
//! shards must stay within 0.03 recall of the full fan-out while doing
//! substantially less distance work. This is the tier-1 CI guard for
//! the routing layer — if the partitioner or router regresses (bad
//! centroids, wrong routing order, broken ghost stitching), recall
//! collapses long before 0.03.

use knng::api::{KMeans, Searcher, ShardedSearcher};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::metrics::recall::{exact_neighbor_ids, recall_vs_exact};
use knng::nndescent::Params;
use knng::search::SearchParams;

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

#[test]
fn kmeans_top2_of_4_recall_stays_within_the_gate() {
    let (all, _) = SynthClustered::new(4096, 8, 8, 0xF14).generate_labeled();
    let corpus = slice_rows(&all, 0, 3896);
    let queries = slice_rows(&all, 3896, 200);
    let params = Params::default().with_k(20).with_seed(4).with_max_iters(8);
    let k = 10;
    let sp = SearchParams::default();

    let sharded =
        ShardedSearcher::build_partitioned(&corpus, 4, &params, &KMeans::new(4)).unwrap();
    let exact = exact_neighbor_ids(&corpus, &queries, k);

    let (full, full_stats) = sharded.search_batch(&queries, k, &sp);
    let (routed, routed_stats) = sharded.search_batch_routed(&queries, k, &sp, 2);

    let full_recall = recall_vs_exact(&full, &exact);
    let routed_recall = recall_vs_exact(&routed, &exact);
    assert!(full_recall > 0.9, "full fan-out recall {full_recall} unexpectedly low");
    assert!(
        routed_recall >= full_recall - 0.03,
        "routed recall {routed_recall} fell more than 0.03 below full fan-out {full_recall}"
    );

    // the whole point of routing: visit half the shards, skip a
    // commensurate share of the distance work (route scoring included)
    assert_eq!(routed_stats.shard_visits, 2 * queries.n() as u64);
    assert!(
        (full_stats.dist_evals as f64) >= 1.3 * routed_stats.dist_evals as f64,
        "expected ≥1.3× eval reduction: full {} vs routed {}",
        full_stats.dist_evals,
        routed_stats.dist_evals
    );
}
