//! Chaos suite: the serving stack under deterministic fault injection
//! (`knng::testing::faults`). Proves the fault-tolerance contract:
//!
//! * a contained worker panic degrades one batch and the next batch is
//!   bit-identical to the healthy fan-out;
//! * a dead worker is respawned (bounded budget) and, once buried, the
//!   pool keeps serving answers **equal to an honest fan-out over the
//!   surviving shards** — never garbage, never a hang;
//! * deadline expiry yields a typed `Degradation` within bounded wall
//!   time; a lost reply never hangs a batch;
//! * degradation flows end to end: pool → `ServeFront` ticket → KNNQv1
//!   `Degraded` frame, with `Health` probes exposing per-shard
//!   liveness over the wire.
//!
//! The fault plan is process-global, so every test serializes on
//! `FAULT_LOCK` and clears the plan via an RAII guard (panic-safe);
//! the suite also runs green under `RUST_TEST_THREADS=1` in CI. The
//! seeded soak logs its seed; replay with `PALLAS_FAULT_SEED`.

use knng::api::{
    DegradeCause, FrontConfig, Neighbor, PoolConfig, Searcher, ServeFront, ShardPool,
    ShardState, ShardedSearcher,
};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::net::{NetClient, NetServer, ServerConfig};
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::testing::faults::{self, site, FaultAction, FaultPlan, Trigger};
use knng::testing::assert_neighbors_bitwise_eq;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// The process-global fault plan admits one chaos test at a time.
static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Serialize + guarantee `faults::clear()` on every exit path, so a
/// failing test cannot leak its plan into the next one.
struct ChaosGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl ChaosGuard {
    fn take() -> Self {
        let g = FAULT_LOCK.lock().unwrap_or_else(|p| p.into_inner());
        faults::clear();
        Self(g)
    }
}

impl Drop for ChaosGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

/// Corpus + query tile + a 3-shard searcher, deterministic per seed.
fn stack(seed: u64) -> (ShardedSearcher, AlignedMatrix) {
    let (all, _) = SynthClustered::new(660, 8, 4, seed).generate_labeled();
    let corpus = slice_rows(&all, 0, 600);
    let queries = slice_rows(&all, 600, 40);
    let params = Params::default().with_k(10).with_seed(seed).with_reorder(true);
    (ShardedSearcher::build(&corpus, 3, &params).unwrap(), queries)
}

/// One pool batch through the deadline entry point.
fn batch(
    pool: &ShardPool,
    queries: &AlignedMatrix,
    k: usize,
    sp: &SearchParams,
    deadline: Option<Instant>,
) -> (Vec<Vec<Neighbor>>, Option<knng::api::Degradation>) {
    let (res, _, degr) =
        pool.search_batch_deadline_owned(Arc::new(queries.clone()), k, sp, None, deadline);
    (res, degr)
}

/// Every shard slot except `missing`, ascending.
fn survivors(shard_count: usize, missing: &[u32]) -> Vec<usize> {
    (0..shard_count).filter(|s| !missing.contains(&(*s as u32))).collect()
}

#[test]
fn contained_panic_degrades_one_batch_then_recovers_bitwise() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(11);
    let k = 6;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 3).unwrap();

    // shard 1's very first search panics; the worker contains it
    faults::install(FaultPlan::new().panic_at(site::WORKER_SEARCH, 1, 0));
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    let degr = degr.expect("a contained panic must be reported");
    assert_eq!(degr.shards_missing, vec![1]);
    assert_eq!(degr.cause, DegradeCause::ShardPanicked);
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[0, 2]);
    assert_neighbors_bitwise_eq(&honest, &got, "degraded batch vs honest 2-shard fan-out");

    let stats = pool.stats();
    assert_eq!(stats.contained_panics, 1, "the panic was contained and counted");
    assert_eq!(stats.respawns, 0, "containment needs no respawn");
    assert!(stats.all_healthy(), "a contained panic does not kill the shard");

    // the worker rebuilt its scratch; the next batch is pristine
    faults::clear();
    let (again, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none(), "recovered pool must not report degradation");
    assert_neighbors_bitwise_eq(&expect, &again, "post-panic batch vs healthy fan-out");
}

#[test]
fn dead_worker_is_respawned_and_answers_recover_bitwise() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(23);
    let k = 5;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 3).unwrap();

    // worker 0 (owning shard 0) dies on its first job receipt, once
    faults::install(FaultPlan::new().rule(
        site::WORKER_JOB,
        Some(0),
        Trigger::Nth(0),
        FaultAction::Die,
    ));
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    let degr = degr.expect("a mid-batch worker death must be reported");
    assert_eq!(degr.shards_missing, vec![0]);
    // the exact cause races between ShardDead (thread observed
    // finished) and ReplyLost (it had not flipped yet); both are a
    // truthful description of a worker that died after accepting a job
    assert!(
        matches!(degr.cause, DegradeCause::ShardDead | DegradeCause::ReplyLost),
        "unexpected cause {:?}",
        degr.cause
    );
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[1, 2]);
    assert_neighbors_bitwise_eq(&honest, &got, "death batch vs honest survivor fan-out");

    // supervision respawns it — at the failing batch's end if the
    // thread's exit was already observable, else before the next
    // dispatch; either way the next batch is pristine
    faults::clear();
    let (again, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none());
    assert_neighbors_bitwise_eq(&expect, &again, "post-respawn batch vs healthy fan-out");
    let stats = pool.stats();
    assert_eq!(stats.respawns, 1, "supervision must respawn the dead worker");
    assert!(stats.all_healthy(), "respawned worker leaves no shard dead");
}

#[test]
fn buried_shard_keeps_pool_serving_survivors_deterministically() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(37);
    let k = 7;
    let sp = SearchParams::default();
    let pool = ShardPool::with_config(
        &sharded,
        PoolConfig { threads: 3, respawn_budget: 0, ..Default::default() },
    )
    .unwrap();

    // worker 0 dies on every job; with a zero respawn budget the first
    // death buries shard 0 permanently
    faults::install(FaultPlan::new().die_always(site::WORKER_JOB, 0));
    let (_, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_some(), "the killing batch must be reported degraded");

    // faults off: the shard stays dead, and from the next dispatch on
    // the degradation is fully deterministic — sender gone, cause
    // ShardDead (the burial lands at the killing batch's end or at the
    // next dispatch, whichever observes the thread's exit first)
    faults::clear();
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[1, 2]);
    for round in 0..3 {
        let (got, degr) = batch(&pool, &queries, k, &sp, None);
        let degr = degr.expect("a buried shard must always be reported");
        assert_eq!(degr.shards_missing, vec![0], "round {round}");
        assert_eq!(degr.cause, DegradeCause::ShardDead, "round {round}");
        assert_neighbors_bitwise_eq(
            &honest,
            &got,
            &format!("round {round}: buried-shard pool vs honest survivor fan-out"),
        );
    }
    let stats = pool.stats();
    assert_eq!(stats.shards[0], ShardState::Dead);
    assert_eq!(stats.shards[1], ShardState::Healthy);
    assert_eq!(stats.shards[2], ShardState::Healthy);
    assert_eq!(stats.dead_shards(), vec![0]);
}

#[test]
fn deadline_expiry_is_typed_bounded_and_honest() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(41);
    let k = 6;
    let sp = SearchParams::default();
    let pool = ShardPool::new(&sharded, 3).unwrap();

    // a generous deadline under no faults changes nothing, bit for bit
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let (got, degr) =
        batch(&pool, &queries, k, &sp, Some(Instant::now() + Duration::from_secs(30)));
    assert!(degr.is_none(), "a met deadline must not degrade");
    assert_neighbors_bitwise_eq(&expect, &got, "generous deadline vs no deadline");

    // shard 2's reply stalls far past the budget: the batch returns on
    // time with a typed record, merged from the shards that made it
    faults::install(FaultPlan::new().delay_always(
        site::WORKER_REPLY,
        2,
        Duration::from_millis(400),
    ));
    let t0 = Instant::now();
    let (got, degr) =
        batch(&pool, &queries, k, &sp, Some(Instant::now() + Duration::from_millis(40)));
    let waited = t0.elapsed();
    assert!(
        waited < Duration::from_millis(350),
        "deadline batch must not wait out the stall (took {waited:?})"
    );
    let degr = degr.expect("an expired deadline must be reported");
    assert_eq!(degr.shards_missing, vec![2]);
    assert_eq!(degr.cause, DegradeCause::DeadlineExpired);
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[0, 1]);
    assert_neighbors_bitwise_eq(&honest, &got, "deadline batch vs honest on-time fan-out");
    assert!(pool.stats().deadline_misses >= 1);
    // dropping the pool joins the stalled worker; bounded by the stall
}

#[test]
fn lost_reply_never_hangs_a_batch() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(53);
    let k = 6;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 3).unwrap();

    // shard 1's first reply is lost in transit; the worker stays alive.
    // With no deadline the batch must still terminate (channel
    // disconnect, not a timeout) and say what went missing.
    faults::install(FaultPlan::new().drop_at(site::WORKER_REPLY, 1, 0));
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    let degr = degr.expect("a lost reply must be reported");
    assert_eq!(degr.shards_missing, vec![1]);
    assert_eq!(degr.cause, DegradeCause::ReplyLost);
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[0, 2]);
    assert_neighbors_bitwise_eq(&honest, &got, "lost-reply batch vs honest fan-out");
    assert_eq!(pool.stats().lost_replies, 1);
    assert!(pool.stats().all_healthy(), "a lost reply is not a dead shard");

    faults::clear();
    let (again, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none());
    assert_neighbors_bitwise_eq(&expect, &again, "post-loss batch vs healthy fan-out");
}

#[test]
fn front_tickets_carry_degradation_and_health() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(67);
    let k = 5;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 3).unwrap();
    let cfg = FrontConfig {
        k,
        params: sp,
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    };
    let front = ServeFront::spawn(pool, queries.dim(), cfg).unwrap();

    // healthy path: a generous budget degrades nothing and answers are
    // bit-identical to the direct fan-out
    let row = queries.row_logical(0).to_vec();
    let served = front
        .submit_with_deadline(row.clone(), Duration::from_secs(30))
        .unwrap()
        .wait()
        .unwrap();
    assert!(served.degradation.is_none());
    assert_neighbors_bitwise_eq(
        std::slice::from_ref(&expect[0]),
        std::slice::from_ref(&served.neighbors),
        "front deadline ticket vs direct fan-out",
    );
    let health = front.health().expect("a pool-backed front exposes health");
    assert!(health.all_healthy());

    // stalled shard + tight budget: the ticket itself says degraded
    faults::install(FaultPlan::new().delay_always(
        site::WORKER_REPLY,
        1,
        Duration::from_millis(400),
    ));
    let served = front
        .submit_with_deadline(row, Duration::from_millis(40))
        .unwrap()
        .wait()
        .unwrap();
    let degr = served.degradation.expect("the ticket must carry the degradation");
    assert_eq!(degr.cause, DegradeCause::DeadlineExpired);
    assert!(degr.shards_missing.contains(&1));
    faults::clear();

    let totals = front.shutdown();
    assert!(totals.degraded >= 1, "degraded windows are counted: {totals:?}");
}

#[test]
fn wire_serves_degraded_frames_and_health_from_a_wounded_pool() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(79);
    let k = 6;
    let sp = SearchParams::default();
    let pool = ShardPool::with_config(
        &sharded,
        PoolConfig { threads: 3, respawn_budget: 0, ..Default::default() },
    )
    .unwrap();
    let cfg = FrontConfig {
        k,
        params: sp,
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    };
    let front = ServeFront::spawn(pool, queries.dim(), cfg).unwrap();

    // wound the pool: worker 0 dies on its first job and stays buried.
    // The second submission guarantees the burial is observed (its
    // dispatch supervises before sending), so health is deterministic.
    faults::install(FaultPlan::new().die_always(site::WORKER_JOB, 0));
    for _ in 0..2 {
        let _ = front
            .submit_with_k(queries.row_logical(0).to_vec(), k)
            .unwrap()
            .wait()
            .unwrap();
    }
    faults::clear();
    let health = front.health().unwrap();
    assert_eq!(health.dead_shards(), vec![0], "shard 0 must be buried: {health:?}");

    // the wounded front goes on the wire; clients see typed degradation
    let server_cfg = ServerConfig {
        workers: 2,
        read_timeout: Duration::from_secs(5),
        write_timeout: Duration::from_secs(5),
        ..Default::default()
    };
    let handle = NetServer::bind("127.0.0.1:0", front, server_cfg).unwrap().spawn().unwrap();
    let mut client = NetClient::connect(handle.addr()).unwrap();

    let h = client.health().unwrap();
    assert_eq!(h.shards_alive, vec![false, true, true]);
    assert_eq!(h.threads, 3);

    let (results, windows, degr) = client.query_batch_deadline(&queries, k, None, 0).unwrap();
    assert_eq!(windows.len(), queries.n());
    let degr = degr.expect("a dead shard must surface as a Degraded frame");
    assert_eq!(degr.shards_missing, vec![0]);
    assert_eq!(degr.cause, DegradeCause::ShardDead);
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[1, 2]);
    assert_neighbors_bitwise_eq(&honest, &results, "wire degraded answers vs honest fan-out");

    handle.stop().unwrap();
}

#[test]
fn killed_replica_fails_over_bitwise_clean() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(101);
    let k = 6;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::with_config(
        &sharded,
        PoolConfig { threads: 3, replicas: 2, ..Default::default() },
    )
    .unwrap();

    // the primary copy of shard 0 (replica-0 worker 0) dies on its
    // first job receipt; the replica answers instead, so the batch is
    // bitwise equal to the healthy full fan-out with zero degradation
    faults::install(FaultPlan::new().rule(
        site::WORKER_JOB,
        Some(0),
        Trigger::Nth(0),
        FaultAction::Die,
    ));
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none(), "failover must keep the answer whole: {degr:?}");
    assert_neighbors_bitwise_eq(&expect, &got, "killed-primary batch vs healthy fan-out");
    let stats = pool.stats();
    assert!(stats.failovers >= 1, "the replica dispatch must be counted: {stats:?}");
    assert_eq!(stats.hedges_sent, 0, "failover is not hedging");
    assert_eq!(stats.contained_panics, 0);

    // and with the fault gone the pool keeps serving clean full answers
    faults::clear();
    let (again, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none());
    assert_neighbors_bitwise_eq(&expect, &again, "post-failover batch vs healthy fan-out");
    assert!(pool.stats().all_healthy(), "the dead primary respawns; no shard is lost");
}

#[test]
fn all_replicas_dead_degrades_with_the_replica_count() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(103);
    let k = 6;
    let sp = SearchParams::default();
    let pool = ShardPool::with_config(
        &sharded,
        PoolConfig { threads: 3, replicas: 2, respawn_budget: 0, ..Default::default() },
    )
    .unwrap();

    // both copies of shard 0 die on every job; with a zero respawn
    // budget the first batch exhausts the whole replica set
    faults::install(
        FaultPlan::new()
            .die_always(site::WORKER_JOB, 0)
            .die_always(site::REPLICA_JOB, faults::replica_index(1, 0)),
    );
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    let degr = degr.expect("a shard with no replicas left must degrade");
    assert_eq!(degr.shards_missing, vec![0]);
    assert_eq!(
        degr.replicas_tried,
        vec![2],
        "the killing batch must have consulted both replicas: {degr:?}"
    );
    let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &[1, 2]);
    assert_neighbors_bitwise_eq(&honest, &got, "exhausted-replica batch vs honest fan-out");

    // both copies are buried: from here on the degradation is the
    // typed, deterministic ShardDead of the unreplicated pool
    faults::clear();
    for round in 0..2 {
        let (got, degr) = batch(&pool, &queries, k, &sp, None);
        let degr = degr.expect("a shard with every replica buried stays degraded");
        assert_eq!(degr.shards_missing, vec![0], "round {round}");
        assert_eq!(degr.cause, DegradeCause::ShardDead, "round {round}");
        assert_eq!(
            degr.replicas_tried,
            vec![0],
            "round {round}: a fully buried shard is never dispatchable"
        );
        assert_neighbors_bitwise_eq(
            &honest,
            &got,
            &format!("round {round}: buried-replicas pool vs honest fan-out"),
        );
    }
    let stats = pool.stats();
    assert_eq!(stats.dead_shards(), vec![0], "dead only when ALL replicas are gone");
    assert_eq!(stats.replica_states[0].len(), 2);
    assert!(
        stats.replica_states[0].iter().all(|s| *s == ShardState::Dead),
        "both copies of shard 0 are buried: {stats:?}"
    );
}

#[test]
fn hedged_straggler_wins_bitwise_clean() {
    let _chaos = ChaosGuard::take();
    let (sharded, queries) = stack(107);
    let k = 6;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::with_config(
        &sharded,
        PoolConfig { threads: 3, replicas: 2, hedge_us: 20_000, ..Default::default() },
    )
    .unwrap();

    // the primary copy of shard 0 stalls its reply far past the hedge
    // delay; the hedge re-sends the job to the replica, whose reply
    // wins — the answer is whole and bitwise equal to the healthy run
    faults::install(FaultPlan::new().delay_always(
        site::WORKER_REPLY,
        0,
        Duration::from_millis(1_500),
    ));
    let t0 = Instant::now();
    let (got, degr) = batch(&pool, &queries, k, &sp, None);
    let waited = t0.elapsed();
    assert!(degr.is_none(), "a won hedge must leave the answer whole: {degr:?}");
    assert_neighbors_bitwise_eq(&expect, &got, "hedged-straggler batch vs healthy fan-out");
    assert!(
        waited < Duration::from_millis(1_200),
        "the batch must not wait out the straggler (took {waited:?})"
    );
    let stats = pool.stats();
    assert!(stats.hedges_sent >= 1, "the hedge must be counted: {stats:?}");
    assert!(stats.hedge_wins >= 1, "the replica's reply won: {stats:?}");
    assert!(stats.hedge_wins <= stats.hedges_sent);
    assert_eq!(stats.failovers, 0, "hedging is not failover");
    assert!(stats.all_healthy(), "a straggler is not a dead shard");

    // fault off: hedging stays armed but never fires on a healthy pool
    faults::clear();
    let (again, degr) = batch(&pool, &queries, k, &sp, None);
    assert!(degr.is_none());
    assert_neighbors_bitwise_eq(&expect, &again, "post-straggler batch vs healthy fan-out");
}

#[test]
fn seeded_soak_terminates_and_clean_batches_stay_bitwise() {
    let _chaos = ChaosGuard::take();
    let seed = faults::seed_from_env(0x5EED_CA05);
    eprintln!("chaos soak seed: {seed:#x} (replay with PALLAS_FAULT_SEED={seed})");
    let (sharded, queries) = stack(97);
    let k = 6;
    let sp = SearchParams::default();
    let (expect, _) = sharded.search_batch(&queries, k, &sp);
    let pool = ShardPool::new(&sharded, 3).unwrap();

    // replies vanish at random (deterministically per seed); workers
    // stay alive, so every batch must terminate and honestly report
    // exactly the shards whose replies were lost
    faults::install(FaultPlan::new().rule(
        site::WORKER_REPLY,
        None,
        Trigger::Seeded { seed, prob: 0.25 },
        FaultAction::Drop,
    ));
    let mut degraded_batches = 0u32;
    for round in 0..12 {
        let (got, degr) = batch(&pool, &queries, k, &sp, None);
        match degr {
            None => {
                assert_neighbors_bitwise_eq(
                    &expect,
                    &got,
                    &format!("soak round {round}: clean batch vs healthy fan-out"),
                );
            }
            Some(d) => {
                degraded_batches += 1;
                assert!(!d.shards_missing.is_empty());
                assert_eq!(d.cause, DegradeCause::ReplyLost);
                // `keep` may legitimately be empty (every reply lost):
                // the honest answer is then the empty fan-out
                let keep = survivors(3, &d.shards_missing);
                let (honest, _) = sharded.search_batch_subset(&queries, k, &sp, &keep);
                assert_neighbors_bitwise_eq(
                    &honest,
                    &got,
                    &format!("soak round {round}: degraded batch vs honest fan-out"),
                );
            }
        }
        assert!(pool.stats().all_healthy(), "dropped replies never kill shards");
    }
    assert!(degraded_batches >= 1, "prob 0.25 over 36 replies should fire at least once");
}
