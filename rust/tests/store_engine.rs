//! Storage-engine integration: the acceptance gates for the `KNNIv2`
//! zero-copy segment + WAL-backed delta + compaction stack.
//!
//! * `KNNIv1` → `KNNIv2` conversion answers bit-identically to the
//!   legacy bundle it came from.
//! * mmap and heap-copy modes parse identical bytes and answer
//!   bit-identically; the mmap open copies no corpus bytes.
//! * Inserts and deletes are visible to the next query, survive a
//!   simulated crash via WAL replay, and a torn WAL tail replays only
//!   the records that provably committed.
//! * Tombstoned base ids never surface in results.
//! * After `compact()` the in-memory state answers bit-identically to
//!   a fresh open of the compacted segment, within a recall gate
//!   against brute force over the live rows.
//! * The same mutations work over the wire against a server with a
//!   mutable store attached; read-only servers reject them typed.
//! * A serving front with the answer cache ON answers bit-identically
//!   to a cache-OFF front across interleaved insert/delete/compact
//!   (the cache flushes on every mutation-epoch bump).

use knng::api::{FrontConfig, Neighbor, OriginalId, Searcher, ServeFront};
use knng::dataset::clustered::SynthClustered;
use knng::dataset::AlignedMatrix;
use knng::net::{NetClient, NetServer, ServerConfig, ServerHandle};
use knng::nndescent::Params;
use knng::search::SearchParams;
use knng::store::{
    convert_v1_to_v2, BaseSegment, MutableIndex, SharedMutableIndex, StoreConfig, StoreMode,
};
use knng::testing::assert_neighbors_bitwise_eq;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Rows `[from, from+count)` of `data` as a fresh matrix.
fn slice_rows(data: &AlignedMatrix, from: usize, count: usize) -> AlignedMatrix {
    let rows: Vec<f32> =
        (from..from + count).flat_map(|i| data.row_logical(i).to_vec()).collect();
    AlignedMatrix::from_rows(count, data.dim(), &rows)
}

/// A fresh scratch dir per test (parallel tests must not collide).
fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("knng_store_engine_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Corpus + queries + a built `Index` saved as a `KNNIv2` segment at
/// `<dir>/base.knni2`. Returns (corpus, queries, segment path).
fn build_segment(
    dir: &Path,
    n: usize,
    n_queries: usize,
    dim: usize,
    seed: u64,
    reorder: bool,
) -> (AlignedMatrix, AlignedMatrix, PathBuf) {
    let (all, _) = SynthClustered::new(n + n_queries, dim, 6, seed).generate_labeled();
    let corpus = slice_rows(&all, 0, n);
    let queries = slice_rows(&all, n, n_queries);
    let params = Params::default().with_k(10).with_seed(seed).with_reorder(reorder);
    let index = knng::api::IndexBuilder::new().data(corpus.clone()).params(params).build().unwrap();
    let path = dir.join("base.knni2");
    index.save_segment(&path).unwrap();
    (corpus, queries, path)
}

/// A config that never auto-compacts, so tests control the fold.
fn manual_cfg() -> StoreConfig {
    StoreConfig { auto_compact_ratio: 0.0, ..Default::default() }
}

/// A row far outside the synthetic clusters — uniquely identifiable by
/// a zero-distance self-query.
fn beacon_row(dim: usize, salt: f32) -> Vec<f32> {
    (0..dim).map(|j| 1000.0 + salt + j as f32).collect()
}

#[test]
fn v1_to_v2_conversion_answers_bitwise_identically() {
    // the format acceptance gate: a legacy KNNIv1 bundle converted to
    // a KNNIv2 segment serves the same ids and the same distance BITS
    // through the same MutableIndex facade
    let dir = scratch_dir("v1_to_v2");
    let (all, _) = SynthClustered::new(640, 12, 5, 41).generate_labeled();
    let corpus = slice_rows(&all, 0, 560);
    let queries = slice_rows(&all, 560, 80);
    let params = Params::default().with_k(10).with_seed(41).with_reorder(true);
    let index = knng::api::IndexBuilder::new().data(corpus).params(params).build().unwrap();

    let v1 = dir.join("legacy.knni");
    let v2 = dir.join("converted.knni2");
    index.save(&v1).unwrap();
    convert_v1_to_v2(&v1, &v2).unwrap();

    let legacy = MutableIndex::open_with(&v1, manual_cfg()).unwrap();
    let converted = MutableIndex::open_with(&v2, manual_cfg()).unwrap();
    assert!(matches!(legacy.base(), BaseSegment::Legacy(_)), "v1 must take the legacy path");
    assert!(matches!(converted.base(), BaseSegment::V2(_)), "v2 must take the segment path");
    assert_eq!(legacy.len(), converted.len());
    assert_eq!(legacy.dim(), converted.dim());
    assert_eq!(converted.generation(), 0);

    for sp in [SearchParams::default(), SearchParams { ef: 64, ..Default::default() }] {
        let (a, _) = legacy.search_batch(&queries, 8, &sp);
        let (b, _) = converted.search_batch(&queries, 8, &sp);
        assert_neighbors_bitwise_eq(&a, &b, "KNNIv1 vs converted KNNIv2");
    }
}

#[test]
fn mmap_and_copy_modes_are_bitwise_interchangeable() {
    let dir = scratch_dir("modes");
    let (_corpus, queries, path) = build_segment(&dir, 520, 60, 16, 43, false);

    let mmap = MutableIndex::open_with(
        &path,
        StoreConfig { mode: Some(StoreMode::Mmap), ..manual_cfg() },
    )
    .unwrap();
    let copy = MutableIndex::open_with(
        &path,
        StoreConfig { mode: Some(StoreMode::Copy), ..manual_cfg() },
    )
    .unwrap();
    assert_eq!(mmap.len(), 520);
    assert_eq!(copy.len(), 520);

    let sp = SearchParams::default();
    let (a, _) = mmap.search_batch(&queries, 10, &sp);
    let (b, _) = copy.search_batch(&queries, 10, &sp);
    assert_neighbors_bitwise_eq(&a, &b, "mmap vs heap-copy");
}

#[cfg(unix)]
#[test]
fn mmap_open_serves_the_corpus_zero_copy() {
    // the tentpole gate: opening a KNNIv2 segment under mmap backs the
    // data matrix with the mapping itself — no full-corpus heap copy
    let dir = scratch_dir("zero_copy");
    let (_corpus, queries, path) = build_segment(&dir, 480, 20, 12, 47, true);

    let store = MutableIndex::open_with(
        &path,
        StoreConfig { mode: Some(StoreMode::Mmap), ..manual_cfg() },
    )
    .unwrap();
    match store.base() {
        BaseSegment::V2(seg) => {
            assert_eq!(seg.mode(), StoreMode::Mmap);
            assert!(
                !seg.data().is_owned(),
                "data matrix must borrow the mapping, not own a heap copy"
            );
        }
        BaseSegment::Legacy(_) => panic!("KNNIv2 segment opened through the legacy path"),
    }
    // ...and it still answers
    let (res, _) = store.search_batch(&queries, 5, &SearchParams::default());
    assert!(res.iter().all(|r| r.len() == 5));
}

#[test]
fn inserts_and_deletes_are_visible_to_the_next_query() {
    let dir = scratch_dir("visibility");
    let (_corpus, _queries, path) = build_segment(&dir, 400, 10, 8, 53, false);
    let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
    let dim = store.dim();
    let sp = SearchParams::default();

    let beacon = beacon_row(dim, 0.0);
    store.insert(90_001, &beacon).unwrap();
    assert_eq!(store.len(), 401);
    assert_eq!(store.delta_len(), 1);

    let (hits, _) = store.search(&beacon, 3, &sp);
    assert_eq!(hits[0].id, OriginalId(90_001), "inserted row must win its own query");
    assert_eq!(hits[0].dist.to_bits(), 0.0f32.to_bits(), "self-distance must be exactly zero");

    assert!(store.delete(90_001).unwrap(), "live id must report deleted");
    assert_eq!(store.len(), 400);
    let (hits, _) = store.search(&beacon, 3, &sp);
    assert!(hits.iter().all(|nb| nb.id != OriginalId(90_001)), "deleted id resurfaced");
    assert!(!store.delete(90_001).unwrap(), "double-delete must be a reported no-op");
}

#[test]
fn wal_replay_restores_the_exact_pre_crash_answers() {
    // simulated crash: drop the handle without compacting, reopen, and
    // the replayed state must answer bitwise-identically
    let dir = scratch_dir("wal_replay");
    let (corpus, queries, path) = build_segment(&dir, 450, 40, 12, 59, false);
    let sp = SearchParams::default();

    let before = {
        let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
        for i in 0..12u32 {
            store.insert(80_000 + i, corpus.row_logical(i as usize)).unwrap();
        }
        for id in [3u32, 44, 101] {
            assert!(store.delete(id).unwrap());
        }
        assert_eq!(store.delta_len(), 12);
        assert_eq!(store.tombstone_count(), 3);
        let (res, _) = store.search_batch(&queries, 10, &sp);
        res
        // handle dropped here: nothing flushed beyond the WAL appends
    };

    let store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
    assert_eq!(store.delta_len(), 12, "replay must restore every delta row");
    assert_eq!(store.tombstone_count(), 3, "replay must restore every tombstone");
    let (after, _) = store.search_batch(&queries, 10, &sp);
    assert_neighbors_bitwise_eq(&before, &after, "pre-crash vs replayed");
}

#[test]
fn torn_wal_tail_replays_only_complete_records() {
    let dir = scratch_dir("torn_tail");
    let (_corpus, _queries, path) = build_segment(&dir, 300, 10, 8, 61, false);
    let dim = 8;
    {
        let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
        store.insert(70_001, &beacon_row(dim, 1.0)).unwrap();
        store.insert(70_002, &beacon_row(dim, 2.0)).unwrap();
    }
    let wal_path = {
        let mut os = path.as_os_str().to_os_string();
        os.push(".wal");
        PathBuf::from(os)
    };
    let pristine = std::fs::read(&wal_path).unwrap();
    // record = len u32 | body | crc u64
    let body1 = u32::from_le_bytes(pristine[..4].try_into().unwrap()) as usize;
    let rec1_end = 4 + body1 + 8;
    assert!(pristine.len() > rec1_end, "expected a second record after byte {rec1_end}");

    // scenario 1: the crash tore the second append mid-record
    std::fs::write(&wal_path, &pristine[..rec1_end + 5]).unwrap();
    {
        let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
        assert_eq!(store.delta_len(), 1, "only the complete record may replay");
        assert_eq!(
            store.wal_bytes(),
            rec1_end as u64,
            "open must truncate the torn tail back to the last good record"
        );
        assert!(store.delete(70_001).unwrap(), "replayed insert must be live");
        assert!(!store.delete(70_002).unwrap(), "torn insert must NOT be live");
    }

    // scenario 2: the second record is complete but its body is corrupt
    let mut corrupt = pristine.clone();
    corrupt[rec1_end + 6] ^= 0xFF; // a body byte of record 2
    std::fs::write(&wal_path, &corrupt).unwrap();
    {
        let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
        assert_eq!(store.delta_len(), 1, "checksum-failing record must not replay");
        assert!(store.delete(70_001).unwrap());
        assert!(!store.delete(70_002).unwrap());
    }
}

#[test]
fn tombstoned_base_ids_never_surface() {
    let dir = scratch_dir("tombstones");
    let (_corpus, queries, path) = build_segment(&dir, 500, 30, 12, 67, true);
    let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();
    let sp = SearchParams { ef: 64, ..Default::default() };
    let k = 8;

    // delete every query's current best answer, then re-ask
    let (before, _) = store.search_batch(&queries, k, &sp);
    let victims: std::collections::HashSet<u32> =
        before.iter().map(|r| r[0].id.get()).collect();
    for &id in &victims {
        assert!(store.delete(id).unwrap(), "base id {id} must be live before masking");
    }
    assert_eq!(store.tombstone_count(), victims.len());

    let (after, _) = store.search_batch(&queries, k, &sp);
    for (qi, res) in after.iter().enumerate() {
        assert_eq!(res.len(), k, "masking must not starve query {qi} below k");
        for nb in res {
            assert!(!victims.contains(&nb.id.get()), "query {qi} surfaced tombstoned id {}", nb.id.get());
        }
    }
}

/// Exact top-`k` external ids by brute force over `(id, row)` pairs.
fn exact_topk(live: &[(u32, Vec<f32>)], query: &[f32], k: usize) -> Vec<u32> {
    let mut scored: Vec<(f32, u32)> = live
        .iter()
        .map(|(id, row)| {
            let d: f32 = row.iter().zip(query).map(|(a, b)| (a - b) * (a - b)).sum();
            (d, *id)
        })
        .collect();
    scored.sort_unstable_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    scored.into_iter().take(k).map(|(_, id)| id).collect()
}

#[test]
fn compaction_matches_a_fresh_open_bitwise_within_a_recall_gate() {
    let dir = scratch_dir("compaction");
    let n = 500;
    let (corpus, queries, path) = build_segment(&dir, n, 40, 12, 71, false);
    let (extra, _) = SynthClustered::new(60, 12, 6, 72).generate_labeled();
    let mut store = MutableIndex::open_with(&path, manual_cfg()).unwrap();

    for i in 0..extra.n() {
        store.insert(60_000 + i as u32, extra.row_logical(i)).unwrap();
    }
    let deleted: Vec<u32> = (0..20).collect();
    for &id in &deleted {
        assert!(store.delete(id).unwrap());
    }

    let stats = store.compact().unwrap();
    assert_eq!(stats.rows, n - 20 + 60);
    assert_eq!(stats.folded, 60);
    assert_eq!(stats.dropped, 20);
    assert_eq!(stats.generation, 1);
    assert_eq!(store.generation(), 1);
    assert_eq!(store.len(), n - 20 + 60);
    assert_eq!(store.delta_len(), 0, "compaction must empty the delta");
    assert_eq!(store.tombstone_count(), 0, "compaction must clear the tombstones");
    assert_eq!(store.wal_bytes(), 0, "compaction must reset the WAL");

    // the durability gate: post-compaction in-memory state IS a fresh
    // open of the segment on disk, bit for bit
    let sp = SearchParams { ef: 64, ..Default::default() };
    let k = 10;
    let (in_memory, _) = store.search_batch(&queries, k, &sp);
    let fresh = MutableIndex::open_with(&path, manual_cfg()).unwrap();
    assert_eq!(fresh.generation(), 1);
    assert_eq!(fresh.len(), store.len());
    let (reopened, _) = fresh.search_batch(&queries, k, &sp);
    assert_neighbors_bitwise_eq(&in_memory, &reopened, "post-compact vs fresh open");

    // the quality gate: the repaired graph still finds the true
    // neighbors of the mutated corpus
    let live: Vec<(u32, Vec<f32>)> = (0..n as u32)
        .filter(|id| !deleted.contains(id))
        .map(|id| (id, corpus.row_logical(id as usize).to_vec()))
        .chain((0..extra.n()).map(|i| (60_000 + i as u32, extra.row_logical(i).to_vec())))
        .collect();
    let mut hit = 0usize;
    let mut total = 0usize;
    for (qi, res) in in_memory.iter().enumerate() {
        let exact = exact_topk(&live, queries.row_logical(qi), k);
        let got: std::collections::HashSet<u32> = res.iter().map(|nb| nb.id.get()).collect();
        hit += exact.iter().filter(|id| got.contains(id)).count();
        total += exact.len();
    }
    let recall = hit as f64 / total as f64;
    assert!(recall >= 0.80, "post-compaction recall {recall:.3} fell below the 0.80 gate");
}

#[test]
fn legacy_v1_bundles_keep_serving_through_the_facade() {
    let dir = scratch_dir("legacy");
    let (all, _) = SynthClustered::new(460, 8, 4, 73).generate_labeled();
    let corpus = slice_rows(&all, 0, 400);
    let queries = slice_rows(&all, 400, 60);
    let params = Params::default().with_k(8).with_seed(73).with_reorder(true);
    let index = knng::api::IndexBuilder::new().data(corpus).params(params).build().unwrap();
    let v1 = dir.join("legacy.knni");
    index.save(&v1).unwrap();

    let sp = SearchParams::default();
    let (expect, _) = index.search_batch(&queries, 6, &sp);
    let store = MutableIndex::open(&v1).unwrap();
    assert_eq!(store.generation(), 0, "legacy bundles predate the generation counter");
    let (got, _) = store.search_batch(&queries, 6, &sp);
    assert_neighbors_bitwise_eq(&expect, &got, "Index::load vs MutableIndex facade");
}

/// Front + server over one `SharedMutableIndex` clone pair.
fn spawn_store_server(path: &Path, attach_store: bool) -> (SharedMutableIndex, ServerHandle) {
    let shared = SharedMutableIndex::open_with(path, manual_cfg()).unwrap();
    let dim = shared.dim();
    let front_cfg = FrontConfig {
        k: 3,
        params: SearchParams::default(),
        max_batch: 16,
        max_wait: Duration::from_millis(2),
        ..Default::default()
    };
    let front = ServeFront::spawn(shared.clone(), dim, front_cfg).unwrap();
    let server_cfg = ServerConfig {
        workers: 2,
        read_timeout: Duration::from_secs(10),
        write_timeout: Duration::from_secs(10),
        ..Default::default()
    };
    let server = NetServer::bind("127.0.0.1:0", front, server_cfg).unwrap();
    let server = if attach_store { server.with_store(shared.clone()) } else { server };
    (shared, server.spawn().unwrap())
}

#[test]
fn mutations_over_the_wire_are_visible_to_the_next_query() {
    let dir = scratch_dir("wire_mutations");
    let (_corpus, _queries, path) = build_segment(&dir, 420, 10, 8, 79, false);
    let (shared, handle) = spawn_store_server(&path, true);
    let dim = 8;

    let mut client = NetClient::connect(handle.addr()).unwrap();
    let info = client.ping().unwrap();
    assert_eq!(info.n, 420, "ping must report the store's live count");
    let gen0 = shared.generation();

    // insert, then find the row through the batching front
    let beacon = beacon_row(dim, 9.0);
    let (generation, live) = client.insert(77_000, &beacon).unwrap();
    assert_eq!(generation, gen0);
    assert_eq!(live, 421);
    let tile = AlignedMatrix::from_rows(1, dim, &beacon);
    let (res, _) = client.query_batch(&tile, 3, None).unwrap();
    assert_eq!(res[0][0].id, OriginalId(77_000), "wire insert invisible to wire query");
    assert_eq!(res[0][0].dist.to_bits(), 0.0f32.to_bits());

    // delete: gone from the very next query
    let (was_live, _, live) = client.delete(77_000).unwrap();
    assert!(was_live);
    assert_eq!(live, 420);
    let (res, _) = client.query_batch(&tile, 3, None).unwrap();
    assert!(res[0].iter().all(|nb: &Neighbor| nb.id != OriginalId(77_000)));
    let (was_live, _, _) = client.delete(77_000).unwrap();
    assert!(!was_live, "double delete must report a no-op, not fail");

    // compact over the wire: generation bumps, the answers keep coming
    let (generation, live) = client.compact().unwrap();
    assert_eq!(generation, gen0 + 1);
    assert_eq!(live, 420);
    assert_eq!(shared.generation(), gen0 + 1);
    let (res, _) = client.query_batch(&tile, 3, None).unwrap();
    assert_eq!(res[0].len(), 3);
    assert_eq!(client.ping().unwrap().n, 420);

    drop(client);
    let (net, _front) = handle.stop().unwrap();
    assert_eq!(net.protocol_errors, 0);
}

#[test]
fn answer_cache_stays_bit_identical_across_mutations() {
    // the epoch-keyed-cache gate: a front with the answer cache ON
    // must answer bitwise-identically to a cache-OFF front over the
    // same mutable store through an interleaved insert/delete/compact
    // sequence. The cache flushes whenever the store's mutation epoch
    // moves, so a hit can never replay a stale answer.
    let dir = scratch_dir("cache_epoch");
    let (_corpus, queries, path) = build_segment(&dir, 440, 12, 8, 89, false);
    let shared = SharedMutableIndex::open_with(&path, manual_cfg()).unwrap();
    let dim = shared.dim();

    let front_cfg = |cache: usize| FrontConfig {
        k: 5,
        params: SearchParams::default(),
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        answer_cache: cache,
        ..Default::default()
    };
    let cached = ServeFront::spawn(shared.clone(), dim, front_cfg(64)).unwrap();
    let plain = ServeFront::spawn(shared.clone(), dim, front_cfg(0)).unwrap();

    fn ask_all(front: &ServeFront, queries: &AlignedMatrix) -> Vec<Vec<Neighbor>> {
        (0..queries.n())
            .map(|i| {
                front.submit(queries.row_logical(i).to_vec()).unwrap().wait().unwrap().neighbors
            })
            .collect()
    }
    fn ask_one(front: &ServeFront, row: &[f32]) -> Vec<Neighbor> {
        front.submit(row.to_vec()).unwrap().wait().unwrap().neighbors
    }

    // two passes over the same queries: the second must be served (in
    // part) from the cache, and both must match the uncached front
    let epoch0 = shared.mutation_epoch();
    for phase in ["cold corpus", "warm corpus"] {
        let a = ask_all(&cached, &queries);
        let b = ask_all(&plain, &queries);
        assert_neighbors_bitwise_eq(&a, &b, phase);
    }
    assert!(cached.stats().cache_hits > 0, "repeated identical queries must hit the cache");

    // the staleness probe: cache the beacon's pre-insert answer...
    let beacon = beacon_row(dim, 4.0);
    let pre = ask_one(&cached, &beacon);
    assert!(pre.iter().all(|nb| nb.id != OriginalId(88_000)));

    // ...then insert it. A stale cache would replay `pre`; the flushed
    // cache must surface the new row, bit-identical to the uncached
    // front.
    shared.insert(88_000, &beacon).unwrap();
    assert!(shared.mutation_epoch() > epoch0, "insert must bump the mutation epoch");
    let a = ask_one(&cached, &beacon);
    assert_eq!(a[0].id, OriginalId(88_000), "cached front replayed a pre-insert answer");
    assert_eq!(a[0].dist.to_bits(), 0.0f32.to_bits());
    let b = ask_one(&plain, &beacon);
    assert_neighbors_bitwise_eq(&[a], &[b], "post-insert beacon");

    // delete: gone from the cached front's very next answer too
    assert!(shared.delete(88_000).unwrap());
    let a = ask_one(&cached, &beacon);
    assert!(
        a.iter().all(|nb| nb.id != OriginalId(88_000)),
        "cached front resurfaced a deleted id"
    );
    let b = ask_one(&plain, &beacon);
    assert_neighbors_bitwise_eq(&[a], &[b], "post-delete beacon");
    let a = ask_all(&cached, &queries);
    let b = ask_all(&plain, &queries);
    assert_neighbors_bitwise_eq(&a, &b, "post-delete corpus");

    // compact: answers are unchanged by construction but the epoch
    // still bumps (the conservative flush), and cache-on == cache-off
    // holds across the segment swap
    let before = shared.mutation_epoch();
    shared.compact().unwrap();
    assert!(shared.mutation_epoch() > before, "compaction must bump the mutation epoch");
    let a = ask_all(&cached, &queries);
    let b = ask_all(&plain, &queries);
    assert_neighbors_bitwise_eq(&a, &b, "post-compact corpus");

    cached.shutdown();
    plain.shutdown();
}

#[test]
fn read_only_servers_reject_mutations_with_a_typed_error() {
    let dir = scratch_dir("read_only");
    let (_corpus, _queries, path) = build_segment(&dir, 300, 10, 8, 83, false);
    let (_shared, handle) = spawn_store_server(&path, false);

    let mut client = NetClient::connect(handle.addr()).unwrap();
    let err = client.insert(1, &beacon_row(8, 0.0)).unwrap_err();
    assert!(
        err.to_string().contains("read-only"),
        "expected a read-only rejection, got: {err:#}"
    );
    // the connection survives the rejection
    let info = client.ping().unwrap();
    assert_eq!(info.dim, 8);

    drop(client);
    handle.stop().unwrap();
}
